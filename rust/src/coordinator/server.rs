//! Batching inference server.
//!
//! Clients exchange plain `Vec<f32>` with a single worker thread through
//! bounded channels; the worker *creates* its execution backend (see
//! [`super::backend`]) at startup — PJRT handles are not `Send`, and the
//! native backend's scratch is single-owner — assembles dynamic batches
//! (up to `max_batch`, or until `max_wait` expires), quantizes inputs
//! through the b-posit codec where the serving format calls for it,
//! executes, and fans results back out. A full queue rejects with a
//! `Busy` error — backpressure.
//!
//! Failure discipline: every admitted request gets an answer. Requests
//! that outlive `cfg.deadline` while queued are answered with
//! [`ServeError::DeadlineExceeded`] instead of occupying a batch slot;
//! a failed batch execution answers every member with
//! [`ServeError::BackendFailed`] and bumps
//! `positron_batch_failures_total` — never a silently dropped channel.
//!
//! Steady-state allocation discipline: the staging buffer is built once
//! and reused; quantization runs through the sharded vector codec in
//! place, and the backend returns logits borrowed from its own reused
//! scratch. The codec and execute stages are timed separately into
//! [`Metrics`].
//!
//! Observability: every request carries a process-unique trace id and a
//! [`StageTimer`]; the worker attributes queue-wait, staging, input
//! codec, execute, and readout time per batch (wall times at stage
//! boundaries — no timing inside lane loops) and each [`Response`]
//! carries the merged per-stage breakdown back to the caller. When
//! `cfg.tracing` is on, completed request and batch spans land in the
//! server's [`Tracer`] ring for `GET /debug/tracez`; when off, only the
//! span recording stops — stage timers, histograms, and counters stay
//! live, and the numeric path is identical either way (logits are
//! bit-identical with tracing on or off; tests gate on this).

use std::fmt;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{anyhow, Result};

use super::backend;
use super::backend::{BackendKind, InferenceBackend, NativeBackend, PjrtBackend, WeightFormat};
use super::metrics::Metrics;
use super::trace::{self, SpanRecord, Stage, StageTimer, Tracer};
use crate::runtime::ModelWeights;

/// Server tuning knobs.
///
/// Prefer [`ServerConfig::builder`], which validates the knob set at
/// build time (e.g. `max_inflight >= max_batch`). Field-literal
/// construction with `..Default::default()` remains supported as the
/// legacy path so existing call sites compile unchanged, but it skips
/// validation and new knobs may not be checked for coherence.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max requests per executed batch (additionally capped by the
    /// backend's own limit, e.g. the PJRT model's static batch).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Bounded queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Quantize inputs through the serving format's codec before
    /// execution (b-posit32 roundtrip for the BP32 tier; a no-op for f32
    /// and for BP64, where every f32 input is exactly representable).
    pub quantize_inputs: bool,
    /// Which executor the worker builds ([`BackendKind::Native`] needs
    /// only `weights.json`; [`BackendKind::Pjrt`] needs the `runtime`
    /// feature plus compiled HLO artifacts).
    pub backend: BackendKind,
    /// How the model weights are stored and multiplied. Shared with the
    /// backend layer — this replaces the old
    /// `model_file.contains("f32")` string sniffing.
    pub weight_format: WeightFormat,
    /// HLO artifact for the PJRT backend (ignored by the native one).
    pub model_file: String,
    /// Per-request deadline: a request still *queued* this long after
    /// submission is answered with [`ServeError::DeadlineExceeded`]
    /// instead of occupying a batch slot. `None` disables.
    pub deadline: Option<Duration>,
    /// Record completed request/batch spans into the server's
    /// [`Tracer`] ring (`GET /debug/tracez`). Off switches span
    /// *retention* only — stage timing, histograms, and counters stay
    /// on, and logits are bit-identical either way.
    pub tracing: bool,
    /// Admission budget for the HTTP front end: at most this many
    /// requests may be in flight (submitted but unanswered) through one
    /// listener before new requests are shed with a fast 503 +
    /// `Retry-After`, *before* their body is parsed. Must be at least
    /// `max_batch` (the builder validates this) or admission control
    /// would starve the batcher of full batches.
    pub max_inflight: usize,
    /// Certify 1 in this many served requests through the backend's
    /// interval twin ([`backend::InferenceBackend::certify`]): the
    /// worker keeps a per-tier request counter and certifies every
    /// `certify_rate`-th answered request — deterministic, no wallclock
    /// or randomness in the choice. `0` disables (the default; the
    /// interval model is then never even built).
    pub certify_rate: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            quantize_inputs: true,
            backend: BackendKind::Native,
            weight_format: WeightFormat::Bp32,
            model_file: WeightFormat::Bp32.model_file().into(),
            deadline: None,
            tracing: true,
            max_inflight: 256,
            certify_rate: 0,
        }
    }
}

impl ServerConfig {
    /// A config serving `format`, with the PJRT artifact name kept in
    /// sync for builds that select the PJRT backend.
    pub fn for_format(format: WeightFormat) -> ServerConfig {
        ServerConfig {
            weight_format: format,
            model_file: format.model_file().into(),
            ..Default::default()
        }
    }

    /// Start building a validated config (the preferred construction
    /// path — see [`ServerConfigBuilder`]).
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }
}

/// Builder for [`ServerConfig`] with build-time validation:
///
/// ```
/// use positron::coordinator::{backend::WeightFormat, ServerConfig};
/// use std::time::Duration;
///
/// let cfg = ServerConfig::builder()
///     .format(WeightFormat::Bp64)
///     .deadline(Duration::from_millis(250))
///     .max_inflight(512)
///     .build()
///     .unwrap();
/// assert_eq!(cfg.max_inflight, 512);
/// ```
///
/// `build` rejects incoherent knob sets (zero batch/queue sizes, an
/// admission budget below the batch size, a zero deadline, an empty
/// model file) instead of letting them surface as hangs or permanent
/// 503s at serve time.
#[derive(Clone, Debug)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    /// Max requests per executed batch.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Max time the batcher waits to fill a batch.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.cfg.max_wait = d;
        self
    }

    /// Bounded queue depth (backpressure beyond this).
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.cfg.queue_depth = n;
        self
    }

    /// Quantize inputs through the serving format's codec.
    pub fn quantize_inputs(mut self, on: bool) -> Self {
        self.cfg.quantize_inputs = on;
        self
    }

    /// Which executor the worker builds.
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self
    }

    /// Serving weight format; keeps the PJRT artifact name in sync
    /// (call [`ServerConfigBuilder::model_file`] *after* this to
    /// override the artifact).
    pub fn format(mut self, format: WeightFormat) -> Self {
        self.cfg.weight_format = format;
        self.cfg.model_file = format.model_file().into();
        self
    }

    /// HLO artifact for the PJRT backend (ignored by the native one).
    pub fn model_file(mut self, file: &str) -> Self {
        self.cfg.model_file = file.into();
        self
    }

    /// Per-request deadline (answered with a deadline error when still
    /// queued past this). Use [`ServerConfigBuilder::no_deadline`] to
    /// clear.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.cfg.deadline = Some(d);
        self
    }

    /// Disable the per-request deadline (the default).
    pub fn no_deadline(mut self) -> Self {
        self.cfg.deadline = None;
        self
    }

    /// Retain request/batch spans for `GET /debug/tracez`.
    pub fn tracing(mut self, on: bool) -> Self {
        self.cfg.tracing = on;
        self
    }

    /// Listener admission budget (max in-flight requests before
    /// load-shedding).
    pub fn max_inflight(mut self, n: usize) -> Self {
        self.cfg.max_inflight = n;
        self
    }

    /// Certify 1 in `n` served requests through the backend's interval
    /// twin (`0` disables — the default).
    pub fn certify_rate(mut self, n: usize) -> Self {
        self.cfg.certify_rate = n;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ServerConfig> {
        let c = &self.cfg;
        if c.max_batch == 0 {
            return Err(anyhow!("ServerConfig: max_batch must be at least 1"));
        }
        if c.queue_depth == 0 {
            return Err(anyhow!("ServerConfig: queue_depth must be at least 1"));
        }
        if c.max_inflight < c.max_batch {
            return Err(anyhow!(
                "ServerConfig: max_inflight ({}) must be >= max_batch ({}) — a smaller \
                 admission budget could never fill a batch",
                c.max_inflight,
                c.max_batch
            ));
        }
        if c.deadline == Some(Duration::ZERO) {
            return Err(anyhow!("ServerConfig: a zero deadline rejects every request"));
        }
        if c.model_file.is_empty() {
            return Err(anyhow!("ServerConfig: model_file must not be empty"));
        }
        Ok(self.cfg)
    }
}

/// Why the worker answered a request with an error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request sat queued past `cfg.deadline`.
    DeadlineExceeded,
    /// The backend failed to execute the batch.
    BackendFailed(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::BackendFailed(m) => write!(f, "batch execution failed: {m}"),
        }
    }
}

/// What the worker sends back per request.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// Client-facing error classification (the HTTP layer maps these to
/// status codes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// Malformed request (wrong feature count).
    BadRequest(String),
    /// Queue full — back off and retry.
    Busy,
    /// Server shut down.
    Stopped,
    /// The request's deadline passed while it was queued.
    DeadlineExceeded,
    /// The backend failed to execute the batch.
    Backend(String),
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferError::BadRequest(m) => write!(f, "{m}"),
            InferError::Busy => write!(f, "server busy (queue full)"),
            InferError::Stopped => write!(f, "server stopped"),
            InferError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            InferError::Backend(m) => write!(f, "batch execution failed: {m}"),
        }
    }
}

/// Completion callback attached to a submitted request: the worker
/// invokes it after the answer (success *or* serve error) is sent, so a
/// non-blocking caller — the event-driven HTTP listener — can be woken
/// instead of polling its receivers.
pub type Notify = Arc<dyn Fn() + Send + Sync>;

/// A submitted feature row at the width the client provided. f64 rows
/// are staged losslessly only on 64-bit activation tiers
/// ([`WeightFormat::f64_activations`] + a backend implementing
/// [`backend::InferenceBackend::run64`]); on 32-bit tiers they are
/// narrowed to f32 at admission, exactly as if the client had sent f32.
#[derive(Clone, Debug)]
pub enum Features {
    /// f32 features (the common path).
    F32(Vec<f32>),
    /// f64 features (the lossless 64-bit activation path).
    F64(Vec<f64>),
}

impl Features {
    /// Number of features in the row.
    pub fn len(&self) -> usize {
        match self {
            Features::F32(v) => v.len(),
            Features::F64(v) => v.len(),
        }
    }

    /// True when the row is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as the backend-facing raw-row view.
    fn as_row(&self) -> backend::FeatureRow<'_> {
        match self {
            Features::F32(v) => backend::FeatureRow::F32(v),
            Features::F64(v) => backend::FeatureRow::F64(v),
        }
    }
}

impl From<Vec<f32>> for Features {
    fn from(v: Vec<f32>) -> Features {
        Features::F32(v)
    }
}

impl From<Vec<f64>> for Features {
    fn from(v: Vec<f64>) -> Features {
        Features::F64(v)
    }
}

/// One inference request (internal).
struct Request {
    features: Features,
    submitted: Instant,
    resp: SyncSender<ServeResult>,
    /// Process-unique trace id, echoed back in the [`Response`].
    trace_id: u64,
    /// Stage time spent before submission (HTTP accept/parse; zero for
    /// in-process callers) — merged into the response's breakdown.
    pre: StageTimer,
    /// Invoked by the worker right after this request is answered.
    notify: Option<Notify>,
}

impl Request {
    /// Answer this request and fire its completion callback.
    fn answer(self, result: ServeResult) {
        let _ = self.resp.send(result);
        if let Some(n) = &self.notify {
            n();
        }
    }
}

/// A submitted-but-unanswered request: the waiter half plus the trace id
/// assigned at submission (needed to stamp error bodies for requests
/// that never produce a [`Response`]).
pub struct Pending {
    /// Yields the worker's answer exactly once.
    pub rx: Receiver<ServeResult>,
    /// The id this request carries through spans and error bodies.
    pub trace_id: u64,
    /// Submission instant (for latency accounting by non-blocking
    /// callers).
    pub submitted: Instant,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// This request's process-unique trace id.
    pub trace_id: u64,
    /// Trace id of the batch span that executed this request.
    pub batch_id: u64,
    /// Rows in the executing batch.
    pub batch_rows: u32,
    /// Per-stage breakdown: the caller's pre-submit stages plus this
    /// request's queue wait plus the executing batch's shared stages.
    pub stages: StageTimer,
    /// When this request was sampled by the certify hook
    /// (`cfg.certify_rate`): the largest certified per-logit error-bound
    /// width, echoed to HTTP clients as `certified_error_bound`. `None`
    /// for unsampled requests or backends without an interval twin.
    pub certified_error_bound: Option<f64>,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
    worker: Option<JoinHandle<()>>,
    /// (features, classes) of the served model.
    pub dims: (usize, usize),
    /// The serving weight format (from the startup config).
    format: WeightFormat,
    /// The listener admission budget (from the startup config).
    max_inflight: usize,
}

impl InferenceServer {
    /// Spawn the worker; it builds the configured backend (native by
    /// default — PJRT only when `cfg.backend` says so) and reports
    /// readiness before this returns.
    pub fn start(artifact_dir: PathBuf, cfg: ServerConfig) -> Result<InferenceServer> {
        let c = cfg.clone();
        Self::start_with_factory(
            move || -> Result<Box<dyn InferenceBackend>> {
                match c.backend {
                    BackendKind::Native => {
                        Ok(Box::new(NativeBackend::load(&artifact_dir, c.weight_format)?))
                    }
                    BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(
                        &artifact_dir,
                        &c.model_file,
                        c.weight_format,
                    )?)),
                }
            },
            cfg,
        )
    }

    /// Start a native server over already-loaded (or synthetic) weights
    /// — no artifact files at all. `cfg.weight_format` selects the GEMM
    /// family.
    pub fn start_native(weights: ModelWeights, cfg: ServerConfig) -> Result<InferenceServer> {
        let format = cfg.weight_format;
        Self::start_with_factory(
            move || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(NativeBackend::from_weights(&weights, format)?))
            },
            cfg,
        )
    }

    /// [`start_native`](Self::start_native) with caller-provided metrics
    /// and span sinks — the registry path for in-memory weights.
    pub fn start_native_shared(
        weights: ModelWeights,
        cfg: ServerConfig,
        metrics: Arc<Metrics>,
        tracer: Arc<Tracer>,
    ) -> Result<InferenceServer> {
        let format = cfg.weight_format;
        Self::start_with_factory_shared(
            move || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(NativeBackend::from_weights(&weights, format)?))
            },
            cfg,
            metrics,
            tracer,
        )
    }

    /// Start over an arbitrary backend factory. The factory runs *on the
    /// worker thread* (PJRT handles are not `Send`); startup errors are
    /// reported from here. Tests use this to inject slow or failing
    /// backends.
    pub fn start_with_factory<F>(factory: F, cfg: ServerConfig) -> Result<InferenceServer>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::default());
        let tracer = Arc::new(Tracer::new(cfg.tracing));
        Self::start_with_factory_shared(factory, cfg, metrics, tracer)
    }

    /// Start over a factory with *caller-provided* metrics and span
    /// sinks. This is how a [`ModelRegistry`] makes several tiers share
    /// one `/metrics` surface and one `/debug/tracez` ring behind a
    /// single listener. Span retention follows `tracer.enabled()`, not
    /// `cfg.tracing` — the shared ring's policy wins.
    pub fn start_with_factory_shared<F>(
        factory: F,
        cfg: ServerConfig,
        metrics: Arc<Metrics>,
        tracer: Arc<Tracer>,
    ) -> Result<InferenceServer>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let m2 = metrics.clone();
        let t2 = tracer.clone();
        let format = cfg.weight_format;
        let max_inflight = cfg.max_inflight;
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(usize, usize), String>>(1);
        let worker = std::thread::spawn(move || match factory() {
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
            }
            Ok(backend) => {
                let _ = ready_tx.send(Ok(backend.dims()));
                worker_loop(backend, cfg, rx, m2, t2);
            }
        });
        let dims = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow!("server startup failed: {e}"))?;
        Ok(InferenceServer {
            tx,
            metrics,
            tracer,
            worker: Some(worker),
            dims,
            format,
            max_inflight,
        })
    }

    /// Blocking inference with a typed error. Completes the request span
    /// here (submission-to-answer wall time; no HTTP stages), so
    /// in-process callers show up in `/debug/tracez` too.
    pub fn try_infer(&self, features: Vec<f32>) -> std::result::Result<Response, InferError> {
        let resp = self.try_infer_traced(features, StageTimer::default())?;
        if self.tracer.enabled() {
            self.tracer.push(SpanRecord::request(
                resp.trace_id,
                resp.batch_id,
                resp.batch_rows,
                resp.latency.as_nanos() as u64,
                resp.stages,
            ));
        }
        Ok(resp)
    }

    /// Blocking inference carrying pre-submit stage time (HTTP
    /// accept/parse). Does **not** push a request span — the caller owns
    /// the span's completion so post-response stages (serialize, write)
    /// can be included before it is retained.
    pub fn try_infer_traced(
        &self,
        features: impl Into<Features>,
        pre: StageTimer,
    ) -> std::result::Result<Response, InferError> {
        let pending = self.submit(features, pre, None)?;
        match pending.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(ServeError::DeadlineExceeded)) => Err(InferError::DeadlineExceeded),
            Ok(Err(ServeError::BackendFailed(m))) => Err(InferError::Backend(m)),
            Err(_) => Err(InferError::Stopped),
        }
    }

    /// Non-blocking submission with pre-submit stage time and an
    /// optional completion callback (fired by the worker right after
    /// the answer is sent). The event-driven HTTP listener's dispatch
    /// path: it keeps the [`Pending`] and is woken by `notify` instead
    /// of blocking a thread per request.
    pub fn submit(
        &self,
        features: impl Into<Features>,
        pre: StageTimer,
        notify: Option<Notify>,
    ) -> std::result::Result<Pending, InferError> {
        let mut features = features.into();
        if features.len() != self.dims.0 {
            return Err(InferError::BadRequest(format!(
                "expected {} features, got {}",
                self.dims.0,
                features.len()
            )));
        }
        // 32-bit tiers narrow f64 submissions at admission: the batch
        // staging (and the certify hull) then see exactly what an f32
        // client would have sent. 64-bit tiers keep the full row for
        // lossless staging through `run64`.
        if let Features::F64(v) = &features {
            if !self.format.f64_activations() {
                features = Features::F32(v.iter().map(|&x| x as f32).collect());
            }
        }
        let (rtx, rrx) = sync_channel(1);
        let submitted = Instant::now();
        let trace_id = trace::next_trace_id();
        let req = Request { features, submitted, resp: rtx, trace_id, pre, notify };
        self.metrics.record_request();
        match self.tx.try_send(req) {
            Ok(()) => Ok(Pending { rx: rrx, trace_id, submitted }),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(InferError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(InferError::Stopped),
        }
    }

    /// Blocking inference for one feature vector.
    pub fn infer(&self, features: Vec<f32>) -> Result<Response> {
        self.try_infer(features).map_err(|e| anyhow!("{e}"))
    }

    /// Non-blocking submit returning a waiter for the worker's answer
    /// (response or per-request serve error).
    pub fn infer_async(&self, features: Vec<f32>) -> Result<Receiver<ServeResult>> {
        // Async submissions get a trace id (they appear in their batch
        // span's member list) but no request span — there is no single
        // completion point at which to stamp one.
        self.submit(features, StageTimer::default(), None)
            .map(|p| p.rx)
            .map_err(|e| anyhow!("{e}"))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The weight format this server was configured to serve.
    pub fn weight_format(&self) -> WeightFormat {
        self.format
    }

    /// The admission budget configured for listeners fronting this
    /// server (`cfg.max_inflight`).
    pub fn max_inflight(&self) -> usize {
        self.max_inflight
    }

    /// The server's span sink (the HTTP layer completes and pushes
    /// request spans through this, and `/debug/tracez` renders it).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Close the queue, then join the worker.
        let (dummy_tx, _dummy_rx) = sync_channel::<Request>(1);
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// One registered tier: the `<model>` segment of `POST /v1/infer/<model>`
/// plus its serving stack.
pub struct ModelEntry {
    name: String,
    server: Arc<InferenceServer>,
}

impl ModelEntry {
    /// Route name (the `<model>` path segment).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The tier's batching server.
    pub fn server(&self) -> &Arc<InferenceServer> {
        &self.server
    }
}

/// Route-name → server map behind one listener: `/v1/infer/<model>`
/// dispatches through this, so a single front end serves the f32, bp32,
/// and bp64 tiers side by side.
///
/// All registered tiers share one [`Metrics`] surface and one span ring
/// ([`Tracer`]) — `/metrics` and `/debug/tracez` aggregate across tiers.
/// Weight dedup is automatic: native backends quantize through the
/// process-wide content-hash weight cache, so two tiers over the same
/// source weights share every per-format quantized copy.
///
/// The first registered model is the **default**: legacy `POST /infer`
/// (no model segment) routes to it.
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
}

impl ModelRegistry {
    /// Empty registry with fresh shared sinks. `tracing` sets the span
    /// ring's retention policy for every tier registered into it.
    pub fn new(tracing: bool) -> ModelRegistry {
        ModelRegistry {
            entries: Vec::new(),
            metrics: Arc::new(Metrics::default()),
            tracer: Arc::new(Tracer::new(tracing)),
        }
    }

    /// Wrap one already-running server as a single-model registry (the
    /// compatibility path for [`super::http::serve`]). The registry
    /// adopts the server's metrics and span sinks, so the observability
    /// endpoints are unchanged from serving it directly.
    pub fn from_server(name: &str, server: Arc<InferenceServer>) -> Result<ModelRegistry> {
        let mut reg = ModelRegistry {
            entries: Vec::new(),
            metrics: server.metrics(),
            tracer: server.tracer(),
        };
        reg.insert(name, server)?;
        Ok(reg)
    }

    /// Register a native tier over in-memory weights, sharing the
    /// registry's metrics and span ring.
    pub fn register_native(
        &mut self,
        name: &str,
        weights: ModelWeights,
        cfg: ServerConfig,
    ) -> Result<()> {
        let server = InferenceServer::start_native_shared(
            weights,
            cfg,
            self.metrics.clone(),
            self.tracer.clone(),
        )?;
        self.insert(name, Arc::new(server))
    }

    /// Register a tier over an arbitrary backend factory (tests inject
    /// slow or failing backends through this).
    pub fn register_with_factory<F>(
        &mut self,
        name: &str,
        factory: F,
        cfg: ServerConfig,
    ) -> Result<()>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let server = InferenceServer::start_with_factory_shared(
            factory,
            cfg,
            self.metrics.clone(),
            self.tracer.clone(),
        )?;
        self.insert(name, Arc::new(server))
    }

    /// Add an already-started server under `name`. Route names appear
    /// verbatim as a path segment, so they must be non-empty, unique,
    /// and limited to `[A-Za-z0-9._-]`.
    pub fn insert(&mut self, name: &str, server: Arc<InferenceServer>) -> Result<()> {
        let ok_byte = |b: u8| b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.';
        if name.is_empty() || !name.bytes().all(ok_byte) {
            return Err(anyhow!("invalid model route name {name:?}: use [A-Za-z0-9._-]"));
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(anyhow!("model {name:?} is already registered"));
        }
        self.entries.push(ModelEntry { name: name.to_string(), server });
        Ok(())
    }

    /// Look up a tier by route name.
    pub fn get(&self, name: &str) -> Option<&Arc<InferenceServer>> {
        self.entries.iter().find(|e| e.name == name).map(|e| &e.server)
    }

    /// The default tier (first registered) — the target of legacy
    /// `POST /infer`.
    pub fn default_entry(&self) -> Option<&ModelEntry> {
        self.entries.first()
    }

    /// All registered tiers, in registration order.
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    /// The shared metrics surface (`GET /metrics` renders this).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The shared span ring (`GET /debug/tracez` renders this).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }

    /// The listener's admission budget: the sum of every registered
    /// tier's `max_inflight`. The event loop sheds (fast 503) once this
    /// many requests sit between admission and response write.
    pub fn max_inflight(&self) -> usize {
        self.entries.iter().map(|e| e.server.max_inflight()).sum()
    }
}

/// Hard ceiling on rows staged per batch: the native backend accepts any
/// batch (`max_batch() == usize::MAX`), so an "unlimited" `cfg.max_batch`
/// must not translate into an unbounded up-front staging allocation.
pub const MAX_STAGED_BATCH: usize = 4096;

fn worker_loop(
    mut backend: Box<dyn InferenceBackend>,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
) {
    let (d, c) = backend.dims();
    let max_batch = cfg.max_batch.min(backend.max_batch()).clamp(1, MAX_STAGED_BATCH);
    metrics.set_codec_threads(crate::vector::parallel::num_threads());
    // Staging width, decided once: a 64-bit activation tier over a
    // backend with a lossless f64 path stages f64 (f32 submissions
    // widen exactly, so this is bit-identical to f32 staging for them);
    // everything else stages f32.
    let stage64 = cfg.weight_format.f64_activations() && backend.supports_f64_activations();
    // Persistent staging buffer: the steady-state loop performs no
    // per-request heap allocation on the quantize path.
    let mut x = vec![0f32; if stage64 { 0 } else { max_batch * d }];
    let mut x64 = vec![0f64; if stage64 { max_batch * d } else { 0 }];
    // Deterministic certify sampling: a plain per-tier answered-request
    // counter — every `certify_rate`-th request is certified (no
    // wallclock, no randomness; restart ⇒ same schedule).
    let mut certified_seq: u64 = 0;
    // Deadline admission: a queued request past its deadline is answered
    // immediately and never occupies a batch slot.
    let admit = |r: Request, batch: &mut Vec<Request>| {
        if cfg.deadline.is_some_and(|dl| r.submitted.elapsed() > dl) {
            metrics.record_deadline_expired();
            r.answer(Err(ServeError::DeadlineExceeded));
        } else {
            batch.push(r);
        }
    };
    loop {
        // Block for the first admitted request of a batch.
        let mut batch: Vec<Request> = Vec::new();
        while batch.is_empty() {
            match rx.recv() {
                Ok(r) => admit(r, &mut batch),
                Err(_) => return, // channel closed: shut down
            }
        }
        let wait_until = Instant::now() + cfg.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= wait_until {
                break;
            }
            match rx.recv_timeout(wait_until - now) {
                Ok(r) => admit(r, &mut batch),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Mid-batch cancellation: a request admitted with time to spare
        // can still expire while the batch-fill window runs. Re-check
        // after assembly so an already-dead request never costs GEMM
        // rows; this is counted separately from pre-batch expiry.
        if let Some(dl) = cfg.deadline {
            let (live, expired): (Vec<Request>, Vec<Request>) =
                batch.into_iter().partition(|r| r.submitted.elapsed() <= dl);
            batch = live;
            for r in expired {
                metrics.record_cancelled();
                r.answer(Err(ServeError::DeadlineExceeded));
            }
            if batch.is_empty() {
                continue;
            }
        }
        let rows = batch.len();
        metrics.record_batch(rows);
        // Everything before this instant is queue wait (including the
        // batch-fill wait above); everything after is attributed to a
        // named batch stage, so each member's stage sum tracks its
        // recorded latency.
        let t_batch = Instant::now();
        let mut bt = StageTimer::default();

        // Stage the rows×d input, then quantize in place when the
        // serving format calls for it (only the quantize pass counts as
        // codec time — staging memcpys are batching overhead). The
        // contract lives in `backend::stage_inputs_in_place`, shared
        // with the allocating test-facing wrappers; the staging buffer
        // is reused, so this path performs zero per-request allocation.
        let t_stage = Instant::now();
        for (i, r) in batch.iter().enumerate() {
            // x/x64 are sized to max_batch×d above and admission
            // rejects any request whose feature length is not d.
            if stage64 {
                // lint:allow(no-indexing): see staging-size note above
                let dst = &mut x64[i * d..(i + 1) * d];
                match &r.features {
                    Features::F32(v) => {
                        for (o, &s) in dst.iter_mut().zip(v) {
                            *o = s as f64; // exact widening
                        }
                    }
                    Features::F64(v) => dst.copy_from_slice(v),
                }
            } else {
                // lint:allow(no-indexing): see staging-size note above
                let dst = &mut x[i * d..(i + 1) * d];
                match &r.features {
                    Features::F32(v) => dst.copy_from_slice(v),
                    // Unreachable in practice: submit narrows f64 rows
                    // for 32-bit tiers at admission. Kept total anyway.
                    Features::F64(v) => {
                        for (o, &s) in dst.iter_mut().zip(v) {
                            *o = s as f32;
                        }
                    }
                }
            }
        }
        bt.add_duration(Stage::Staging, t_stage.elapsed());
        let mut codec_worker_ns = 0u64;
        if !stage64 && cfg.quantize_inputs && cfg.weight_format.quantizes_inputs() {
            let t_codec = Instant::now();
            codec_worker_ns =
                // lint:allow(no-indexing): x is resized to rows×d above
                backend::stage_inputs_in_place_timed(cfg.weight_format, &mut x[..rows * d]);
            let codec_wall = t_codec.elapsed();
            metrics.record_codec(codec_wall);
            metrics.record_codec_worker(codec_worker_ns);
            bt.add_duration(Stage::InputCodec, codec_wall);
        }

        let t_exec = Instant::now();
        let run_res = if stage64 {
            // lint:allow(no-indexing): x64 is sized to max_batch×d above
            backend.run64(&x64[..rows * d], rows)
        } else {
            // lint:allow(no-indexing): x is sized to max_batch×d above
            backend.run_traced(&x[..rows * d], rows, &mut bt)
        };
        match run_res {
            Ok(out) => {
                // Copy the logits out per request now — this ends the
                // borrow of `backend`, so the certify hook below can
                // take it mutably. (Each response owns its logits
                // anyway; this is the same allocation as before, moved
                // earlier.)
                let logit_rows: Vec<Vec<f32>> = (0..rows)
                    // lint:allow(no-indexing): the backend contract returns
                    // at least rows×c logits (checked inside run/run_traced)
                    .map(|i| out[i * c..(i + 1) * c].to_vec())
                    .collect();
                let exec_wall = t_exec.elapsed();
                metrics.record_execute(exec_wall);
                if bt.get(Stage::Execute) == 0 && bt.get(Stage::Readout) == 0 {
                    // Backend without stage attribution (the run_traced
                    // default and the run64 path): charge the whole call
                    // to Execute.
                    bt.add_duration(Stage::Execute, exec_wall);
                }
                metrics.record_batch_stages(bt.get(Stage::Staging), bt.get(Stage::Readout));
                let tracing = tracer.enabled();
                let batch_id = trace::next_trace_id();
                let mut members = Vec::with_capacity(if tracing { rows } else { 0 });
                for (r, logits) in batch.into_iter().zip(logit_rows) {
                    // Deterministic 1-in-N certification of the answer
                    // being sent: the interval twin re-derives this
                    // request's logit bounds from its *raw* features and
                    // the served logits must lie inside them.
                    let mut certified_error_bound = None;
                    if cfg.certify_rate > 0 {
                        certified_seq += 1;
                        if certified_seq % cfg.certify_rate as u64 == 0 {
                            if let Some(rep) = backend.certify(r.features.as_row(), &logits) {
                                metrics.record_certified(
                                    rep.max_width,
                                    rep.mean_width,
                                    rep.violation,
                                );
                                certified_error_bound = Some(rep.max_width);
                            }
                        }
                    }
                    let latency = r.submitted.elapsed();
                    metrics.record_latency(latency);
                    let queue_wait = t_batch.saturating_duration_since(r.submitted);
                    metrics.record_queue_wait(queue_wait);
                    let mut stages = r.pre;
                    stages.add_duration(Stage::QueueWait, queue_wait);
                    stages.merge(&bt);
                    if tracing {
                        members.push(r.trace_id);
                    }
                    let trace_id = r.trace_id;
                    r.answer(Ok(Response {
                        logits,
                        latency,
                        trace_id,
                        batch_id,
                        batch_rows: rows as u32,
                        stages,
                        certified_error_bound,
                    }));
                }
                if tracing {
                    tracer.push(SpanRecord::batch(
                        batch_id,
                        members,
                        rows as u32,
                        bt,
                        codec_worker_ns,
                    ));
                }
            }
            Err(e) => {
                // Answer every member explicitly — a failed batch must
                // not look like a dropped connection to clients.
                metrics.record_batch_failure();
                let msg = format!("{e:#}");
                eprintln!("batch execute failed ({rows} requests): {msg}");
                for r in batch {
                    r.answer(Err(ServeError::BackendFailed(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The contract for builds without libxla: *explicitly selecting* the
    /// PJRT backend fails fast with the documented "runtime disabled"
    /// error instead of panicking or hanging. (The default backend is
    /// native and needs no runtime feature at all.)
    #[test]
    #[cfg(not(feature = "runtime"))]
    fn pjrt_backend_without_runtime_feature_fails_with_clear_error() {
        let cfg = ServerConfig { backend: BackendKind::Pjrt, ..Default::default() };
        let err = InferenceServer::start(PathBuf::from("artifacts"), cfg).unwrap_err();
        assert!(err.to_string().contains("runtime disabled"), "{err}");
    }

    /// Native startup against a directory with no weights.json reports a
    /// clean error naming the file.
    #[test]
    fn native_backend_missing_weights_is_clean_error() {
        let cfg = ServerConfig::default();
        let err = InferenceServer::start(PathBuf::from("/nonexistent-dir-positron"), cfg)
            .unwrap_err();
        assert!(err.to_string().contains("weights.json"), "{err}");
    }

    /// Builder validation: a coherent knob set passes through; each
    /// incoherent knob fails with a message naming it.
    #[test]
    fn config_builder_validates_knobs() {
        let cfg = ServerConfig::builder()
            .format(WeightFormat::Bp64)
            .max_batch(8)
            .max_inflight(32)
            .deadline(Duration::from_millis(100))
            .build()
            .unwrap();
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.max_inflight, 32);
        assert_eq!(cfg.weight_format, WeightFormat::Bp64);
        assert_eq!(cfg.model_file, WeightFormat::Bp64.model_file());
        assert_eq!(cfg.deadline, Some(Duration::from_millis(100)));

        let err = ServerConfig::builder().max_batch(16).max_inflight(4).build().unwrap_err();
        assert!(err.to_string().contains("max_inflight"), "{err}");
        let err = ServerConfig::builder().max_batch(0).build().unwrap_err();
        assert!(err.to_string().contains("max_batch"), "{err}");
        let err = ServerConfig::builder().queue_depth(0).build().unwrap_err();
        assert!(err.to_string().contains("queue_depth"), "{err}");
        let err = ServerConfig::builder().deadline(Duration::ZERO).build().unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    /// Registry basics: route-name validation, duplicate rejection,
    /// lookup, default-model selection, and shared sinks across tiers.
    #[test]
    fn registry_validates_and_routes() {
        let w = backend::synth_weights(4, 8, 3, 4, 0xBEEF);
        let mut reg = ModelRegistry::new(false);
        reg.register_native("f32", w.clone(), ServerConfig::for_format(WeightFormat::F32))
            .unwrap();
        reg.register_native("bp64", w, ServerConfig::for_format(WeightFormat::Bp64)).unwrap();

        assert!(reg.insert("f32", reg.get("bp64").unwrap().clone()).is_err(), "duplicate");
        assert!(reg.insert("no/slashes", reg.get("bp64").unwrap().clone()).is_err());
        assert!(reg.insert("", reg.get("bp64").unwrap().clone()).is_err());

        assert_eq!(reg.entries().len(), 2);
        assert_eq!(reg.default_entry().unwrap().name(), "f32");
        assert_eq!(reg.get("bp64").unwrap().weight_format(), WeightFormat::Bp64);
        assert!(reg.get("nope").is_none());
        // Both tiers feed one metrics surface: two in-process requests
        // against different tiers land in the same request counter.
        let m = reg.metrics();
        reg.get("f32").unwrap().try_infer(vec![0.5; 4]).unwrap();
        reg.get("bp64").unwrap().try_infer(vec![0.5; 4]).unwrap();
        assert_eq!(m.snapshot().requests, 2);
        // Budget is the sum across tiers (two defaults).
        assert_eq!(reg.max_inflight(), 512);
    }

    /// Deterministic 1-in-N certification: with `certify_rate = 2`,
    /// exactly every second answered request carries a certified bound
    /// and lands in the certified-request counter — and none violate.
    #[test]
    fn certify_rate_samples_deterministically() {
        let w = backend::synth_weights(4, 8, 3, 4, 0xC0DE);
        let cfg = ServerConfig::builder().certify_rate(2).build().unwrap();
        let srv = InferenceServer::start_native(w.clone(), cfg).unwrap();
        let mut bounds = Vec::new();
        for _ in 0..6 {
            // Sequential blocking requests ⇒ one per batch ⇒ the
            // per-tier counter advances once per request.
            let resp = srv.try_infer(w.golden_x[..4].to_vec()).unwrap();
            bounds.push(resp.certified_error_bound);
        }
        let sampled: Vec<bool> = bounds.iter().map(|b| b.is_some()).collect();
        assert_eq!(sampled, [false, true, false, true, false, true], "{bounds:?}");
        for b in bounds.into_iter().flatten() {
            assert!(b.is_finite() && b > 0.0, "certified bound {b} not finite-positive");
        }
        let s = srv.metrics().snapshot();
        assert_eq!(s.certified_requests, 3);
        assert_eq!(s.certify_violations, 0);
        assert_eq!(s.hist_certify_max_fm.count, 3);
        // Rate 0 (the default): nothing sampled, nothing recorded.
        let srv0 = InferenceServer::start_native(w.clone(), ServerConfig::default()).unwrap();
        let resp = srv0.try_infer(w.golden_x[..4].to_vec()).unwrap();
        assert!(resp.certified_error_bound.is_none());
        assert_eq!(srv0.metrics().snapshot().certified_requests, 0);
    }

    /// f64 submissions: narrowed at admission on 32-bit tiers (bit-equal
    /// to sending the narrowed f32s), staged losslessly on the bp64 tier
    /// (bit-equal to the f64 reference chain).
    #[test]
    fn f64_features_narrow_or_stage_losslessly_by_tier() {
        let w = backend::synth_weights(4, 6, 3, 2, 0xF00D);
        // A value that is NOT f32-exact: narrowing must round it.
        let x64: Vec<f64> = vec![0.1, -0.7, 1.0 + 1e-12, 0.25];
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();

        let srv32 = InferenceServer::start_native(
            w.clone(),
            ServerConfig::for_format(WeightFormat::Bp32),
        )
        .unwrap();
        let via64 = srv32.try_infer_traced(x64.clone(), StageTimer::default()).unwrap();
        let via32 = srv32.try_infer(x32.clone()).unwrap();
        assert_eq!(
            via64.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via32.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "32-bit tier must treat f64 rows as their f32 narrowing"
        );

        let srv64 = InferenceServer::start_native(
            w.clone(),
            ServerConfig::for_format(WeightFormat::Bp64),
        )
        .unwrap();
        let got = srv64.try_infer_traced(x64.clone(), StageTimer::default()).unwrap();
        let want = backend::reference_forward64(&w, &x64);
        assert_eq!(
            got.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "bp64 tier must serve f64 rows losslessly (reference_forward64)"
        );
        // And f32 rows on the bp64 tier still match the widened chain.
        let got32 = srv64.try_infer(x32.clone()).unwrap();
        let want32 =
            backend::reference_forward64(&w, &x32.iter().map(|&v| v as f64).collect::<Vec<_>>());
        assert_eq!(
            got32.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want32.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Length validation applies to f64 rows too.
        assert!(srv64.try_infer_traced(vec![0.5f64; 3], StageTimer::default()).is_err());
    }

    /// The completion notify fires exactly once per answered request —
    /// the event loop depends on this to wake its poller.
    #[test]
    fn submit_notify_fires_on_completion() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let w = backend::synth_weights(4, 8, 3, 4, 0xCAFE);
        let srv = InferenceServer::start_native(w, ServerConfig::default()).unwrap();
        let hits = Arc::new(AtomicUsize::new(0));
        let h2 = hits.clone();
        let notify: Notify = Arc::new(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        });
        let pending =
            srv.submit(vec![0.25; 4], StageTimer::default(), Some(notify.clone())).unwrap();
        let resp = pending.rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits.len(), 3);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        // Validation failures never reach the queue and never notify.
        assert!(srv.submit(vec![0.25; 3], StageTimer::default(), Some(notify)).is_err());
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
