//! Execution backends for the inference server.
//!
//! [`InferenceBackend`] abstracts "run a staged f32 batch through the
//! model" so the serving loop is backend-agnostic:
//!
//! - [`NativeBackend`] — the default. Executes the two-layer MLP entirely
//!   in-tree on the blocked quantized-weight GEMM. Both quantized tiers
//!   (b-posit32 and b-posit64) run **one generic layer routine** over
//!   [`LaneElem`], with weights held as spec-carrying
//!   [`EncodedTensor`]s; the float baseline keeps its plain-f32 path.
//!   Needs only `weights.json` — no libxla, no `runtime` feature — so
//!   the full serving stack runs in default builds and CI.
//! - [`PjrtBackend`] — the original PJRT/XLA executor over the
//!   AOT-compiled HLO artifacts (requires the `runtime` cargo feature
//!   and a libxla install; errors clearly otherwise).
//!
//! # Native layout: weights as A
//!
//! The quantized-weight GEMM family stores *weights* as the A matrix
//! (`C (m×n) = A_bits (m×k) · B (k×n)` with B the activations), so the
//! native backend keeps everything transposed: weights are transposed
//! **once at load** — through the process-wide quantized-weight cache
//! keyed by tensor content hash ([`quantizer::cached_weights_u32`] and
//! friends), so reloading a model skips the transpose/encode entirely —
//! and activations are staged `d×rows` per batch. Layer 1 computes
//! `H (h×rows) = W1ᵀ · Xᵀ`, the bias+ReLU epilogue broadcasts per *row*
//! (contiguous), layer 2 maps `L (c×rows) = W2ᵀ · H`, and the readout
//! transposes back to request-major.
//!
//! # Bit-exactness contract
//!
//! Every native path is **bit-identical to [`reference_forward`]**, the
//! naive scalar forward pass: the blocked GEMM reproduces the naive
//! ascending-`p` accumulation chain exactly (see `vector::gemm`), the
//! lane decode matches the scalar decode bit-for-bit, and the ReLU is an
//! explicit compare (not `max`, whose −0.0 handling is
//! platform-defined). Tests and `serve-bench` gate on this.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::certify::{interval_forward, Interval, IntervalModel};
use crate::error::{anyhow, Result};
use crate::formats::posit::{BP32, BP64};
use crate::runtime::{lit_f32_2d, Literal, LoadedModel, ModelWeights, Runtime};
use crate::testutil::Rng;
use crate::vector::lane::{EncodedTensor, LaneElem};
use crate::vector::{gemm, kernels};

use super::quantizer;
use super::trace::{Stage, StageTimer};

/// How the served model's weight tensors are stored and multiplied.
/// Replaces the old `model_file.contains("f32")` string sniffing with an
/// explicit, shared enum (BP32 is the paper's serving format and the
/// default).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WeightFormat {
    /// b-posit⟨32,6,5⟩ words (the format under test); decode fused into
    /// the GEMM.
    #[default]
    Bp32,
    /// Plain f32 weights — the float baseline.
    F32,
    /// b-posit⟨64,6,5⟩ words over the f64 kernel family (the 64-bit
    /// serving tier; in-range f32 weights encode losslessly).
    Bp64,
}

impl WeightFormat {
    /// Parse a CLI/HTTP format name.
    pub fn parse(s: &str) -> std::result::Result<WeightFormat, String> {
        match s {
            "bp32" => Ok(WeightFormat::Bp32),
            "f32" => Ok(WeightFormat::F32),
            "bp64" => Ok(WeightFormat::Bp64),
            other => Err(format!("unknown weight format {other:?} (expected bp32, f32 or bp64)")),
        }
    }

    /// Short display name ("bp32" / "f32" / "bp64").
    pub fn name(&self) -> &'static str {
        match self {
            WeightFormat::Bp32 => "bp32",
            WeightFormat::F32 => "f32",
            WeightFormat::Bp64 => "bp64",
        }
    }

    /// The HLO artifact the PJRT backend compiles for this format.
    pub fn model_file(&self) -> &'static str {
        match self {
            WeightFormat::F32 => "model_f32.hlo.txt",
            _ => "model_bposit.hlo.txt",
        }
    }

    /// True when the serving contract quantizes *inputs* through this
    /// format's codec before execution: only the BP32 tier — f32 sees
    /// raw inputs (the baseline), and every finite f32 is exactly
    /// representable in ⟨64,6,5⟩, so the BP64 roundtrip is the identity
    /// by construction.
    pub fn quantizes_inputs(&self) -> bool {
        matches!(self, WeightFormat::Bp32)
    }

    /// True when this tier's kernel family accumulates at f64 width, so
    /// f64 HTTP activations can be staged losslessly through
    /// [`InferenceBackend::run64`] instead of narrowed to f32 at
    /// admission.
    pub fn f64_activations(&self) -> bool {
        matches!(self, WeightFormat::Bp64)
    }

    /// Every servable tier, float baseline first (the `--models all`
    /// expansion and the registry tooling iterate this).
    pub const ALL: [WeightFormat; 3] =
        [WeightFormat::F32, WeightFormat::Bp32, WeightFormat::Bp64];
}

/// Which executor the server worker builds at startup.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// In-tree blocked-GEMM executor (default; no libxla).
    #[default]
    Native,
    /// PJRT/XLA executor over the AOT artifacts (`runtime` feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI backend name.
    pub fn parse(s: &str) -> std::result::Result<BackendKind, String> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend {other:?} (expected native or pjrt)")),
        }
    }

    /// Short display name ("native" / "pjrt").
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// A borrowed view of one request's **raw** (pre-staging) feature row,
/// at whichever width the client submitted it. The certify hook
/// ([`InferenceBackend::certify`]) consumes this to build the
/// quantization hulls `[raw, staged]` its interval twin propagates.
#[derive(Clone, Copy, Debug)]
pub enum FeatureRow<'a> {
    /// f32 features (the common path).
    F32(&'a [f32]),
    /// f64 features (the lossless 64-bit activation path).
    F64(&'a [f64]),
}

impl FeatureRow<'_> {
    /// Number of features in the row.
    pub fn len(&self) -> usize {
        match self {
            FeatureRow::F32(x) => x.len(),
            FeatureRow::F64(x) => x.len(),
        }
    }

    /// True when the row is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result of certifying one served request: summary statistics over the
/// per-logit certified error bounds, plus the containment verdict the
/// serving metrics gate on.
#[derive(Clone, Copy, Debug)]
pub struct CertifyReport {
    /// Largest certified bound width across the request's logits (an f64
    /// upper bound on `hi − lo`; +∞ when a bound is poisoned).
    pub max_width: f64,
    /// Mean certified bound width across the request's logits.
    pub mean_width: f64,
    /// True when some served logit fell **outside** its certified bound.
    /// Must never happen — counted as
    /// `positron_certify_violations_total`, gated to 0 in CI.
    pub violation: bool,
}

/// A model executor owned by the server's worker thread. `x` is the
/// staged row-major `rows×d` input batch (already input-quantized by the
/// server when configured); `run` returns the row-major `rows×c` logits
/// borrowed from backend-owned storage — the native backend reuses its
/// scratch across batches (zero per-batch allocation on that path),
/// while the PJRT backend stores whatever buffer the runtime's readback
/// hands it.
///
/// Deliberately **not** `Send`: backends are *created on* the worker
/// thread by a `Send` factory (PJRT handles cannot cross threads) and
/// never leave it.
pub trait InferenceBackend {
    /// Backend display name (metrics/logs).
    fn name(&self) -> &'static str;
    /// (features, classes) of the served model.
    fn dims(&self) -> (usize, usize);
    /// Largest `rows` a single `run` accepts.
    fn max_batch(&self) -> usize;
    /// Execute one staged batch; returns row-major `rows×c` logits.
    fn run(&mut self, x: &[f32], rows: usize) -> Result<&[f32]>;
    /// [`InferenceBackend::run`] plus per-stage timing: backends that can
    /// attribute their work add `Execute`/`Readout` (and `Staging`)
    /// nanoseconds to `timer`. The default ignores the timer so external
    /// backends need no changes — the worker loop falls back to charging
    /// the whole call to `Execute`. Must return bit-identical logits to
    /// `run` (observability never changes the numeric path).
    fn run_traced(&mut self, x: &[f32], rows: usize, timer: &mut StageTimer) -> Result<&[f32]> {
        let _ = timer;
        self.run(x, rows)
    }
    /// True when this backend stages f64 activations losslessly through
    /// [`InferenceBackend::run64`] (only 64-bit accumulation tiers).
    /// The worker loop queries this once at startup to pick its staging
    /// width.
    fn supports_f64_activations(&self) -> bool {
        false
    }
    /// Execute one f64-staged batch (row-major `rows×d`); returns
    /// row-major `rows×c` f32 logits. Only meaningful when
    /// [`supports_f64_activations`](Self::supports_f64_activations) is
    /// true; the default errs so 32-bit backends need no changes.
    fn run64(&mut self, x: &[f64], rows: usize) -> Result<&[f32]> {
        let _ = (x, rows);
        Err(anyhow!("backend {} does not accept f64 activations", self.name()))
    }
    /// Certify one already-served request: re-run it through the
    /// backend's interval twin (raw features in, certified per-logit
    /// `[lo, hi]` bounds out) and check the served `logits` lie inside
    /// their bounds. `None` means this backend cannot certify (the
    /// default — external backends have no interval twin) or the shapes
    /// don't match; the sampling hook then records nothing. Runs off the
    /// batch hot path, 1-in-N requests.
    fn certify(&mut self, raw: FeatureRow<'_>, logits: &[f32]) -> Option<CertifyReport> {
        let _ = (raw, logits);
        None
    }
}

/// One quantized serving tier at lane width `E`: the two transposed
/// weight tensors as spec-carrying [`EncodedTensor`]s (shared via the
/// content-hash cache), biases at the width the kernel family consumes,
/// and the per-tier staging scratch (reused across batches).
struct LaneState<E: LaneElem> {
    wt1: EncodedTensor<E>,
    wt2: EncodedTensor<E>,
    b1: Vec<E>,
    b2: Vec<E>,
    // Reused scratch: activations (d×rows), hidden (h×rows), logits
    // (c×rows), all in the transposed layout.
    xt: Vec<E>,
    ht: Vec<E>,
    lt: Vec<E>,
}

/// Weight tensors in their format-specific encodings. The two quantized
/// tiers are the *same* generic state at different widths — the old
/// three-way per-format `run` duplication is now one generic call.
enum Layers {
    /// b-posit32 tier (`LaneState<f32>`: u32 words, f32 activations).
    Bp32(LaneState<f32>),
    /// Plain-f32 float baseline.
    F32 { wt1: Arc<Vec<f32>>, wt2: Arc<Vec<f32>>, b1: Vec<f32>, b2: Vec<f32> },
    /// b-posit64 tier (`LaneState<f64>`: u64 words, f64 activations).
    Bp64(LaneState<f64>),
}

/// The in-tree executor: dense layers on the blocked (and row-sharded)
/// quantized-weight GEMM, per-layer bias/ReLU epilogues, transposed
/// staging buffers reused across batches.
pub struct NativeBackend {
    format: WeightFormat,
    d: usize,
    h: usize,
    c: usize,
    layers: Layers,
    // Float-baseline scratch (the quantized tiers carry theirs inside
    // their LaneState) plus the request-major readout shared by all.
    xt: Vec<f32>,
    ht: Vec<f32>,
    lt: Vec<f32>,
    out: Vec<f32>,
    /// Interval twin of the served model, decoded lazily on the first
    /// `certify` call (certification off ⇒ zero cost and zero memory).
    certify: Option<CertifyModel>,
    /// Test-only fault injection: serve deliberately wrong (shrunk)
    /// bounds so the violation counter's wiring can be proven live.
    certify_shrink: bool,
}

/// The dequantized interval-twin snapshot at the tier's accumulation
/// width (f32 for the bp32/f32 tiers, f64 for bp64).
enum CertifyModel {
    F32(IntervalModel<f32>),
    F64(IntervalModel<f64>),
}

fn transpose_bits_u32(bits: &[i32], rows: usize, cols: usize) -> Vec<u32> {
    let src: Vec<u32> = bits.iter().map(|&b| b as u32).collect();
    let mut t = vec![0u32; src.len()];
    gemm::transpose(&src, &mut t, rows, cols);
    t
}

fn transpose_f32(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0f32; w.len()];
    gemm::transpose(w, &mut t, rows, cols);
    t
}

fn encode_bp64_transposed(w: &[f32], rows: usize, cols: usize) -> Vec<u64> {
    let t = transpose_f32(w, rows, cols);
    t.iter().map(|&v| quantizer::quantize64_one(v as f64) as u64).collect()
}

/// Tiled transpose-with-convert: `dst` (cols×rows) ← `f(src)` (rows×cols),
/// both row-major, blocked like [`gemm::transpose`] so both sides stream
/// through cache (the per-batch staging/readout of the lane tiers is on
/// the serving hot path; for `E = f32` the convert is the identity and
/// this is exactly the tiled transpose the BP32 tier ran pre-redesign).
// lint:allow(no-indexing): both slices are asserted to rows×cols below and
// every i/j stays under rows/cols, so j*rows+i and i*cols+j are in bounds
fn transpose_map<S: Copy, D: Copy>(
    src: &[S],
    dst: &mut [D],
    rows: usize,
    cols: usize,
    f: impl Fn(S) -> D,
) {
    assert_eq!(src.len(), rows * cols, "transpose_map: src must be rows×cols");
    assert_eq!(dst.len(), rows * cols, "transpose_map: dst must be cols×rows");
    const TB: usize = 32;
    for i0 in (0..rows).step_by(TB) {
        let i1 = rows.min(i0 + TB);
        for j0 in (0..cols).step_by(TB) {
            let j1 = cols.min(j0 + TB);
            for i in i0..i1 {
                for j in j0..j1 {
                    dst[j * rows + i] = f(src[i * cols + j]);
                }
            }
        }
    }
}

/// Advance a stage boundary: charge the time since `*t` to `stage` and
/// reset the boundary. A `None` timer skips the clock read entirely, so
/// the untraced path pays nothing inside the layer pipeline.
fn mark(timer: &mut Option<&mut StageTimer>, stage: Stage, t: &mut Instant) {
    if let Some(tm) = timer.as_deref_mut() {
        let now = Instant::now();
        tm.add_duration(stage, now.duration_since(*t));
        *t = now;
    }
}

/// One generic quantized dense-layer pipeline: stage the f32 batch into
/// the tier's transposed activation buffer, run both layers on the
/// decode-fused blocked GEMM through the typed weight tensors, and read
/// the logits back out request-major as f32. `E = f32` is the BP32 tier,
/// `E = f64` the BP64 tier — the same routine, monomorphized. With a
/// timer, the transpose-in is charged to `Staging`, the GEMM+epilogue
/// pair to `Execute`, and the transpose-out to `Readout` — timing sits
/// at stage boundaries only, never inside lane loops.
fn run_lane_tier<E: LaneElem>(
    st: &mut LaneState<E>,
    x: &[f32],
    rows: usize,
    d: usize,
    h: usize,
    c: usize,
    out: &mut Vec<f32>,
    timer: Option<&mut StageTimer>,
) {
    run_lane_tier_from(st, x, rows, d, h, c, out, E::from_f32, timer)
}

/// The staging-generic body of [`run_lane_tier`]: `stage` converts each
/// source activation into the tier's lane element (`E::from_f32` on the
/// f32 path; the identity on the lossless f64 → f64 path of
/// [`InferenceBackend::run64`]). Everything after staging is identical,
/// so the two entry points share the numeric pipeline bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn run_lane_tier_from<S: Copy, E: LaneElem>(
    st: &mut LaneState<E>,
    x: &[S],
    rows: usize,
    d: usize,
    h: usize,
    c: usize,
    out: &mut Vec<f32>,
    stage: impl Fn(S) -> E,
    mut timer: Option<&mut StageTimer>,
) {
    let mut t = Instant::now();
    st.xt.resize(d * rows, E::ZERO);
    transpose_map(x, &mut st.xt, rows, d, stage);
    mark(&mut timer, Stage::Staging, &mut t);
    st.ht.resize(h * rows, E::ZERO);
    gemm::par_gemm_encoded_fast(&st.wt1, &st.xt, &mut st.ht, rows);
    kernels::bias_relu_rows(&mut st.ht, &st.b1, h, rows);
    st.lt.resize(c * rows, E::ZERO);
    gemm::par_gemm_encoded_fast(&st.wt2, &st.ht, &mut st.lt, rows);
    kernels::bias_rows(&mut st.lt, &st.b2, c, rows);
    mark(&mut timer, Stage::Execute, &mut t);
    out.resize(rows * c, 0.0);
    transpose_map(&st.lt, &mut out[..], c, rows, E::to_f32); // lint:allow(no-indexing): full-range [..] cannot panic
    mark(&mut timer, Stage::Readout, &mut t);
}

impl NativeBackend {
    /// Build from an artifact directory (`weights.json` only).
    pub fn load(dir: &Path, format: WeightFormat) -> Result<NativeBackend> {
        Self::from_weights(&ModelWeights::load_from_dir(dir)?, format)
    }

    /// Build from already-loaded weights. Transposed/encoded weight
    /// tensors come from the process-wide content-hash cache, so loading
    /// the same model twice encodes once; the cached words are adopted
    /// into spec-carrying [`EncodedTensor`]s, so a shape or spec mismatch
    /// is a construction error, not a silent kernel misread.
    pub fn from_weights(w: &ModelWeights, format: WeightFormat) -> Result<NativeBackend> {
        let (d, h, c) = (w.d, w.h, w.c);
        let check = |name: &str, len: usize, want: usize| -> Result<()> {
            if len == want {
                Ok(())
            } else {
                Err(anyhow!("weights: {name} has {len} elements, want {want}"))
            }
        };
        check("w1", w.w1.len(), d * h)?;
        check("b1", w.b1.len(), h)?;
        check("w2", w.w2.len(), h * c)?;
        check("b2", w.b2.len(), c)?;
        let layers = match format {
            WeightFormat::Bp32 => {
                check("w1_bits", w.w1_bits.len(), d * h)?;
                check("w2_bits", w.w2_bits.len(), h * c)?;
                let wt1 = EncodedTensor::from_words(
                    BP32,
                    h,
                    d,
                    quantizer::cached_weights_u32(
                        quantizer::tensor_key_i32("bp32/w1t", d, h, &w.w1_bits),
                        || transpose_bits_u32(&w.w1_bits, d, h),
                    ),
                )?;
                let wt2 = EncodedTensor::from_words(
                    BP32,
                    c,
                    h,
                    quantizer::cached_weights_u32(
                        quantizer::tensor_key_i32("bp32/w2t", h, c, &w.w2_bits),
                        || transpose_bits_u32(&w.w2_bits, h, c),
                    ),
                )?;
                Layers::Bp32(LaneState {
                    wt1,
                    wt2,
                    b1: w.b1.clone(),
                    b2: w.b2.clone(),
                    xt: Vec::new(),
                    ht: Vec::new(),
                    lt: Vec::new(),
                })
            }
            WeightFormat::F32 => {
                let wt1 = quantizer::cached_weights_f32(
                    quantizer::tensor_key_f32("f32/w1t", d, h, &w.w1),
                    || transpose_f32(&w.w1, d, h),
                );
                let wt2 = quantizer::cached_weights_f32(
                    quantizer::tensor_key_f32("f32/w2t", h, c, &w.w2),
                    || transpose_f32(&w.w2, h, c),
                );
                Layers::F32 { wt1, wt2, b1: w.b1.clone(), b2: w.b2.clone() }
            }
            WeightFormat::Bp64 => {
                let wt1 = EncodedTensor::from_words(
                    BP64,
                    h,
                    d,
                    quantizer::cached_weights_u64(
                        quantizer::tensor_key_f32("bp64/w1t", d, h, &w.w1),
                        || encode_bp64_transposed(&w.w1, d, h),
                    ),
                )?;
                let wt2 = EncodedTensor::from_words(
                    BP64,
                    c,
                    h,
                    quantizer::cached_weights_u64(
                        quantizer::tensor_key_f32("bp64/w2t", h, c, &w.w2),
                        || encode_bp64_transposed(&w.w2, h, c),
                    ),
                )?;
                let b1 = w.b1.iter().map(|&v| v as f64).collect();
                let b2 = w.b2.iter().map(|&v| v as f64).collect();
                Layers::Bp64(LaneState {
                    wt1,
                    wt2,
                    b1,
                    b2,
                    xt: Vec::new(),
                    ht: Vec::new(),
                    lt: Vec::new(),
                })
            }
        };
        Ok(NativeBackend {
            format,
            d,
            h,
            c,
            layers,
            xt: Vec::new(),
            ht: Vec::new(),
            lt: Vec::new(),
            out: Vec::new(),
            certify: None,
            certify_shrink: false,
        })
    }

    /// The weight format this backend serves.
    pub fn format(&self) -> WeightFormat {
        self.format
    }

    /// Test-only fault injection: replace every certified bound with a
    /// deliberately wrong (shrunk past the true upper endpoint) interval
    /// so the served logit always falls outside it. Proves the
    /// `positron_certify_violations_total` wiring end to end; never set
    /// in production paths.
    #[doc(hidden)]
    pub fn inject_certify_violation(&mut self, on: bool) {
        self.certify_shrink = on;
    }

    /// Decode the served weights into the interval twin once. `None`
    /// only on an internal shape inconsistency (construction validated
    /// the shapes, so this is fail-closed paranoia, not a live path).
    fn build_certify_model(&self) -> Option<CertifyModel> {
        let (d, h, c) = (self.d, self.h, self.c);
        match &self.layers {
            Layers::Bp32(st) => {
                let mut w1t = vec![0f32; h * d];
                st.wt1.decode_into(&mut w1t);
                let mut w2t = vec![0f32; c * h];
                st.wt2.decode_into(&mut w2t);
                IntervalModel::new(d, h, c, w1t, st.b1.clone(), w2t, st.b2.clone())
                    .map(CertifyModel::F32)
            }
            Layers::F32 { wt1, wt2, b1, b2 } => IntervalModel::new(
                d,
                h,
                c,
                wt1.as_ref().clone(),
                b1.clone(),
                wt2.as_ref().clone(),
                b2.clone(),
            )
            .map(CertifyModel::F32),
            Layers::Bp64(st) => {
                let mut w1t = vec![0f64; h * d];
                st.wt1.decode_into(&mut w1t);
                let mut w2t = vec![0f64; c * h];
                st.wt2.decode_into(&mut w2t);
                IntervalModel::new(d, h, c, w1t, st.b1.clone(), w2t, st.b2.clone())
                    .map(CertifyModel::F64)
            }
        }
    }
}

/// Fold per-logit interval bounds into a [`CertifyReport`].
/// `contained(j)` says whether served logit `j` lies inside `bounds[j]`
/// (the f64-width tiers check through the f32 readout narrowing, so the
/// compare differs per width).
fn certify_report(widths: &[f64], contained: &[bool]) -> CertifyReport {
    let mut max_width = 0.0f64;
    let mut sum = 0.0f64;
    for &w in widths {
        if w > max_width {
            max_width = w;
        }
        sum += w;
    }
    let mean_width = if widths.is_empty() { 0.0 } else { sum / widths.len() as f64 };
    CertifyReport { max_width, mean_width, violation: contained.iter().any(|&ok| !ok) }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        match self.format {
            WeightFormat::Bp32 => "native-bp32",
            WeightFormat::F32 => "native-f32",
            WeightFormat::Bp64 => "native-bp64",
        }
    }

    fn dims(&self) -> (usize, usize) {
        (self.d, self.c)
    }

    fn max_batch(&self) -> usize {
        usize::MAX // no static batch: the GEMM takes any n
    }

    fn run(&mut self, x: &[f32], rows: usize) -> Result<&[f32]> {
        self.run_inner(x, rows, None)
    }

    fn run_traced(&mut self, x: &[f32], rows: usize, timer: &mut StageTimer) -> Result<&[f32]> {
        self.run_inner(x, rows, Some(timer))
    }

    fn supports_f64_activations(&self) -> bool {
        matches!(self.layers, Layers::Bp64(_))
    }

    fn run64(&mut self, x: &[f64], rows: usize) -> Result<&[f32]> {
        let (d, h, c) = (self.d, self.h, self.c);
        if x.len() != rows * d {
            return Err(anyhow!("native backend: {} f64 values staged for {rows}×{d}", x.len()));
        }
        match &mut self.layers {
            Layers::Bp64(st) => {
                // Identity staging: the f64 activations enter the f64
                // kernel family untouched (for f32-exact inputs this is
                // bit-identical to the widening `run` path).
                run_lane_tier_from(st, x, rows, d, h, c, &mut self.out, |v| v, None);
                Ok(&self.out[..rows * c]) // lint:allow(no-indexing): out was resized to rows*c above
            }
            _ => Err(anyhow!(
                "native backend ({}) does not accept f64 activations",
                self.format.name()
            )),
        }
    }

    fn certify(&mut self, raw: FeatureRow<'_>, logits: &[f32]) -> Option<CertifyReport> {
        if raw.len() != self.d || logits.len() != self.c {
            return None;
        }
        if self.certify.is_none() {
            self.certify = self.build_certify_model();
        }
        let quantizes = self.format.quantizes_inputs();
        let shrink = self.certify_shrink;
        // Shrunk-bounds injection (test only): a point interval one
        // float *above* the true upper endpoint can never contain the
        // served logit (which is ≤ hi < next(hi)).
        let maim32 = |b: Interval<f32>| -> Interval<f32> {
            if shrink && !b.is_poisoned() {
                Interval { lo: b.hi.next_float(), hi: b.hi.next_float() }
            } else {
                b
            }
        };
        let maim64 = |b: Interval<f64>| -> Interval<f64> {
            if shrink && !b.is_poisoned() {
                Interval { lo: b.hi.next_float(), hi: b.hi.next_float() }
            } else {
                b
            }
        };
        match self.certify.as_ref()? {
            CertifyModel::F32(m) => {
                // Per-feature quantization hull `[raw, staged]` — the
                // exact pair the serving contract relates (bp32
                // roundtrips inputs; the f32 baseline serves them raw).
                let hull32 = |v: f32| -> Interval<f32> {
                    if quantizes {
                        let q: f32 = quantizer::dequantize_one(quantizer::quantize_one(v));
                        Interval::hull(v, q)
                    } else {
                        Interval::point(v)
                    }
                };
                let xints: Vec<Interval<f32>> = match raw {
                    FeatureRow::F32(x) => x.iter().map(|&v| hull32(v)).collect(),
                    // 32-bit tiers narrow f64 submissions at admission;
                    // certify from the same narrowed row.
                    FeatureRow::F64(x) => x.iter().map(|&v| hull32(v as f32)).collect(),
                };
                let bounds = interval_forward(m, &xints);
                let widths: Vec<f64> = bounds.iter().map(|b| maim32(*b).width_f64()).collect();
                let contained: Vec<bool> =
                    bounds.iter().zip(logits).map(|(b, &l)| maim32(*b).contains(l)).collect();
                Some(certify_report(&widths, &contained))
            }
            CertifyModel::F64(m) => {
                // The bp64 tier stages activations exactly (f32 widens
                // losslessly, run64 is the identity), so every input is
                // a point interval.
                let xints: Vec<Interval<f64>> = match raw {
                    FeatureRow::F32(x) => x.iter().map(|&v| Interval::point(v as f64)).collect(),
                    FeatureRow::F64(x) => x.iter().map(|&v| Interval::point(v)).collect(),
                };
                let bounds = interval_forward(m, &xints);
                let widths: Vec<f64> = bounds.iter().map(|b| maim64(*b).width_f64()).collect();
                // The served logit is the f32 *readout* of the f64
                // accumulator. RNE narrowing is monotone, so any z in
                // [lo, hi] narrows into [fl32(lo), fl32(hi)] — check
                // containment through that narrowed interval.
                let contained: Vec<bool> = bounds
                    .iter()
                    .zip(logits)
                    .map(|(b, &l)| {
                        let b = maim64(*b);
                        !b.is_poisoned() && !l.is_nan() && b.lo as f32 <= l && l <= b.hi as f32
                    })
                    .collect();
                Some(certify_report(&widths, &contained))
            }
        }
    }
}

impl NativeBackend {
    /// Shared body of `run`/`run_traced`: the timer only adds clock reads
    /// at stage boundaries, so both entry points execute the identical
    /// numeric pipeline (traced logits are bit-identical by construction).
    fn run_inner(
        &mut self,
        x: &[f32],
        rows: usize,
        mut timer: Option<&mut StageTimer>,
    ) -> Result<&[f32]> {
        let (d, h, c) = (self.d, self.h, self.c);
        if x.len() != rows * d {
            return Err(anyhow!("native backend: {} values staged for {rows}×{d}", x.len()));
        }
        match &mut self.layers {
            Layers::Bp32(st) => run_lane_tier(st, x, rows, d, h, c, &mut self.out, timer),
            Layers::Bp64(st) => run_lane_tier(st, x, rows, d, h, c, &mut self.out, timer),
            Layers::F32 { wt1, wt2, b1, b2 } => {
                let mut t = Instant::now();
                self.xt.resize(d * rows, 0.0);
                gemm::transpose(x, &mut self.xt, rows, d);
                mark(&mut timer, Stage::Staging, &mut t);
                self.ht.resize(h * rows, 0.0);
                gemm::par_gemm_f32(wt1.as_slice(), &self.xt, &mut self.ht, h, d, rows);
                kernels::bias_relu_rows(&mut self.ht, b1, h, rows);
                self.lt.resize(c * rows, 0.0);
                gemm::par_gemm_f32(wt2.as_slice(), &self.ht, &mut self.lt, c, h, rows);
                kernels::bias_rows(&mut self.lt, b2, c, rows);
                mark(&mut timer, Stage::Execute, &mut t);
                self.out.resize(rows * c, 0.0);
                gemm::transpose(&self.lt, &mut self.out, c, rows);
                mark(&mut timer, Stage::Readout, &mut t);
            }
        }
        Ok(&self.out[..rows * c]) // lint:allow(no-indexing): out was resized to rows*c above
    }
}

/// The PJRT/XLA executor over the AOT-compiled HLO artifacts, with the
/// batch padded to the model's static batch and the input literal
/// refreshed in place. Construction fails with the documented "runtime
/// disabled" error when built without the `runtime` feature.
pub struct PjrtBackend {
    // The PJRT client must outlive its executables.
    _rt: Runtime,
    model: LoadedModel,
    d: usize,
    c: usize,
    model_batch: usize,
    args: Vec<Literal>,
    xpad: Vec<f32>,
    out: Vec<f32>,
}

impl PjrtBackend {
    /// Load the compiled HLO artifact and weight literals for `format`.
    pub fn load(dir: &Path, model_file: &str, format: WeightFormat) -> Result<PjrtBackend> {
        let rt = Runtime::cpu(dir)?;
        let w = ModelWeights::load(&rt)?;
        let model = rt.load(model_file)?;
        let weight_lits = match format {
            WeightFormat::Bp32 => w.bposit_arg_literals()?,
            WeightFormat::F32 => w.f32_arg_literals()?,
            WeightFormat::Bp64 => {
                return Err(anyhow!(
                    "PJRT backend has no b-posit64 model artifact; use the native backend"
                ))
            }
        };
        let xpad = vec![0f32; w.batch * w.d];
        let mut args = Vec::with_capacity(1 + weight_lits.len());
        args.push(lit_f32_2d(&xpad, w.batch, w.d)?);
        args.extend(weight_lits);
        Ok(PjrtBackend {
            _rt: rt,
            model,
            d: w.d,
            c: w.c,
            model_batch: w.batch,
            args,
            xpad,
            out: Vec::new(),
        })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn dims(&self) -> (usize, usize) {
        (self.d, self.c)
    }

    fn max_batch(&self) -> usize {
        self.model_batch
    }

    // lint:allow(no-indexing): xpad is model_batch×d ≥ x.len() (both checked
    // above the slicing), args is built with one literal, and out.len() is
    // checked against rows×c before the final slice
    fn run(&mut self, x: &[f32], rows: usize) -> Result<&[f32]> {
        if rows > self.model_batch {
            return Err(anyhow!("batch {rows} exceeds model batch {}", self.model_batch));
        }
        if x.len() != rows * self.d {
            return Err(anyhow!("pjrt backend: {} values staged for {rows}×{}", x.len(), self.d));
        }
        self.xpad[..x.len()].copy_from_slice(x);
        self.xpad[x.len()..].fill(0.0);
        self.args[0].copy_from_f32(&self.xpad)?;
        self.out = self.model.run_f32(&self.args)?;
        if self.out.len() < rows * self.c {
            return Err(anyhow!(
                "model returned {} logits for a batch of {rows}×{}",
                self.out.len(),
                self.c
            ));
        }
        Ok(&self.out[..rows * self.c])
    }
}

/// Apply the serving input-quantization contract for `format` to a
/// staged buffer, in place and allocation-free — the worker loop's hot
/// path ([`WeightFormat::quantizes_inputs`] says which formats act:
/// b-posit32 roundtrips, f32 and b-posit64 are identities).
pub fn stage_inputs_in_place(format: WeightFormat, xs: &mut [f32]) {
    if format.quantizes_inputs() {
        quantizer::roundtrip_in_place(xs);
    }
}

/// [`stage_inputs_in_place`] plus summed per-thread codec worker
/// nanoseconds (0 for identity formats). Same shard split as the untimed
/// path, so the staged values are bit-identical for any thread count.
pub fn stage_inputs_in_place_timed(format: WeightFormat, xs: &mut [f32]) -> u64 {
    if format.quantizes_inputs() {
        quantizer::roundtrip_in_place_timed(xs)
    } else {
        0
    }
}

/// Stage a feature vector into a reused buffer (cleared + refilled; no
/// allocation once the buffer has grown to the steady-state size).
pub fn stage_inputs_into(format: WeightFormat, x: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend_from_slice(x);
    stage_inputs_in_place(format, &mut out[..]); // lint:allow(no-indexing): full-range [..] cannot panic
}

/// Allocating wrapper over [`stage_inputs_into`] (tests and references).
pub fn stage_inputs(format: WeightFormat, x: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    stage_inputs_into(format, x, &mut out);
    out
}

/// Naive scalar forward pass — the independent reference the native
/// backend must match **bit-for-bit**: one ascending-index accumulator
/// chain per output element (the order the blocked GEMM provably
/// reproduces), scalar fast-path weight decode (bit-identical to the
/// lane decode), explicit-compare ReLU. `x` is one already-staged
/// feature row; returns the `c` logits.
// lint:allow(no-indexing): every index ranges over the d×h×c shapes that
// ModelWeights construction validates; x.len() == d is asserted on entry
pub fn reference_forward(w: &ModelWeights, format: WeightFormat, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.d, "reference_forward: feature length");
    let (d, h, c) = (w.d, w.h, w.c);
    match format {
        WeightFormat::Bp32 => {
            // Deliberately the *independent* scalar fast-path decode, not
            // the lane engine the backend runs on (they are proven
            // bit-identical, but the reference must not share the
            // implementation under test).
            let mut hid = vec![0f32; h];
            for i in 0..h {
                let mut acc = 0f32;
                for p in 0..d {
                    acc += quantizer::fast_bp32_decode(w.w1_bits[p * h + i] as u32) * x[p];
                }
                let v = acc + w.b1[i];
                hid[i] = if v > 0.0 { v } else { 0.0 };
            }
            let mut out = vec![0f32; c];
            for q in 0..c {
                let mut acc = 0f32;
                for i in 0..h {
                    acc += quantizer::fast_bp32_decode(w.w2_bits[i * c + q] as u32) * hid[i];
                }
                out[q] = acc + w.b2[q];
            }
            out
        }
        WeightFormat::F32 => {
            let mut hid = vec![0f32; h];
            for i in 0..h {
                let mut acc = 0f32;
                for p in 0..d {
                    acc += w.w1[p * h + i] * x[p];
                }
                let v = acc + w.b1[i];
                hid[i] = if v > 0.0 { v } else { 0.0 };
            }
            let mut out = vec![0f32; c];
            for q in 0..c {
                let mut acc = 0f32;
                for i in 0..h {
                    acc += w.w2[i * c + q] * hid[i];
                }
                out[q] = acc + w.b2[q];
            }
            out
        }
        WeightFormat::Bp64 => {
            // Widening f32 → f64 is exact, so staging through the f64
            // reference is bit-identical to the historical inline arm.
            let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            reference_forward64(w, &x64)
        }
    }
}

/// f64-activation reference for the BP64 tier: the exact chain of the
/// `Bp64` arm of [`reference_forward`], but with the staged activations
/// entering as f64 — the independent reference for the lossless 64-bit
/// HTTP path ([`InferenceBackend::run64`]), which the native backend
/// must match **bit-for-bit**.
// lint:allow(no-indexing): every index ranges over the d×h×c shapes that
// ModelWeights construction validates; x.len() == d is asserted on entry
pub fn reference_forward64(w: &ModelWeights, x: &[f64]) -> Vec<f32> {
    assert_eq!(x.len(), w.d, "reference_forward64: feature length");
    let (d, h, c) = (w.d, w.h, w.c);
    let dq = |v: f32| -> f64 { quantizer::dequantize64_one(quantizer::quantize64_one(v as f64)) };
    let mut hid = vec![0f64; h];
    for i in 0..h {
        let mut acc = 0f64;
        for p in 0..d {
            acc += dq(w.w1[p * h + i]) * x[p];
        }
        let v = acc + w.b1[i] as f64;
        hid[i] = if v > 0.0 { v } else { 0.0 };
    }
    let mut out = vec![0f32; c];
    for q in 0..c {
        let mut acc = 0f64;
        for i in 0..h {
            acc += dq(w.w2[i * c + q]) * hid[i];
        }
        out[q] = (acc + w.b2[q] as f64) as f32;
    }
    out
}

/// Deterministic synthetic model in the `weights.json` shape: random
/// small weights (quantized to b-posit32 for the bits tensors), golden
/// features on the 1/64 grid (exact under the BP32 roundtrip, so input
/// quantization is a no-op on them), golden logits/labels from
/// [`reference_forward`]. Used by tests, `serve-bench`, and
/// `serve --synthetic` so the native serving stack needs no build-time
/// artifacts at all.
pub fn synth_weights(d: usize, h: usize, c: usize, batch: usize, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut wgt = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| (rng.f64() - 0.5) as f32 * scale).collect()
    };
    let w1 = wgt(d * h, 0.5);
    let b1 = wgt(h, 0.2);
    let w2 = wgt(h * c, 0.5);
    let b2 = wgt(c, 0.2);
    let w1_bits = quantizer::quantize(&w1);
    let w2_bits = quantizer::quantize(&w2);
    let golden_x: Vec<f32> =
        (0..batch * d).map(|_| (rng.below(257) as i64 - 128) as f32 / 64.0).collect();
    let mut w = ModelWeights {
        d,
        h,
        c,
        batch,
        w1,
        b1,
        w2,
        b2,
        w1_bits,
        w2_bits,
        golden_x,
        golden_y: Vec::new(),
        golden_logits_f32: Vec::new(),
        golden_logits_bposit: Vec::new(),
    };
    for g in 0..batch {
        // lint:allow(no-indexing): golden_x holds batch×d values by construction
        let x = &w.golden_x[g * d..(g + 1) * d];
        let lf = reference_forward(&w, WeightFormat::F32, x);
        let lb = reference_forward(&w, WeightFormat::Bp32, x);
        let argmax = lb
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(0);
        w.golden_y.push(argmax);
        w.golden_logits_f32.extend_from_slice(&lf);
        w.golden_logits_bposit.extend_from_slice(&lb);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names() {
        assert_eq!(WeightFormat::parse("bp32").unwrap(), WeightFormat::Bp32);
        assert_eq!(WeightFormat::parse("f32").unwrap(), WeightFormat::F32);
        assert_eq!(WeightFormat::parse("bp64").unwrap(), WeightFormat::Bp64);
        assert!(WeightFormat::parse("fp8").is_err());
        assert_eq!(WeightFormat::default(), WeightFormat::Bp32);
        assert_eq!(WeightFormat::Bp32.model_file(), "model_bposit.hlo.txt");
        assert_eq!(WeightFormat::F32.model_file(), "model_f32.hlo.txt");
        assert!(WeightFormat::Bp32.quantizes_inputs());
        assert!(!WeightFormat::F32.quantizes_inputs());
        assert!(!WeightFormat::Bp64.quantizes_inputs());
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default().name(), "native");
    }

    #[test]
    fn synth_weights_shapes_and_goldens() {
        let w = synth_weights(5, 7, 3, 4, 0xfeed);
        assert_eq!((w.d, w.h, w.c, w.batch), (5, 7, 3, 4));
        assert_eq!(w.w1.len(), 35);
        assert_eq!(w.w1_bits.len(), 35);
        assert_eq!(w.golden_x.len(), 20);
        assert_eq!(w.golden_y.len(), 4);
        assert_eq!(w.golden_logits_f32.len(), 12);
        assert_eq!(w.golden_logits_bposit.len(), 12);
        // Golden features sit on the 1/64 grid ⇒ BP32-roundtrip-exact.
        let staged = stage_inputs(WeightFormat::Bp32, &w.golden_x);
        assert_eq!(
            staged.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            w.golden_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Determinism.
        let w2 = synth_weights(5, 7, 3, 4, 0xfeed);
        assert_eq!(w.w1_bits, w2.w1_bits);
        assert_eq!(w.golden_logits_bposit, w2.golden_logits_bposit);
    }

    #[test]
    fn stage_inputs_into_reuses_buffers_and_matches_wrapper() {
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.31).collect();
        let mut staged = Vec::new();
        stage_inputs_into(WeightFormat::Bp32, &xs, &mut staged);
        let cap = staged.capacity();
        let alloc = stage_inputs(WeightFormat::Bp32, &xs);
        assert_eq!(
            staged.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            alloc.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // In-place primitive agrees with both.
        let mut ip = xs.clone();
        stage_inputs_in_place(WeightFormat::Bp32, &mut ip);
        assert_eq!(
            ip.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            staged.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Steady state: re-staging the same size must not reallocate.
        stage_inputs_into(WeightFormat::Bp32, &xs, &mut staged);
        assert_eq!(staged.capacity(), cap);
        // Identity formats really are identities.
        for f in [WeightFormat::F32, WeightFormat::Bp64] {
            let mut ys = xs.clone();
            stage_inputs_in_place(f, &mut ys);
            assert_eq!(
                ys.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{}",
                f.name()
            );
        }
    }

    #[test]
    fn native_backend_matches_reference_bitwise_all_formats() {
        let w = synth_weights(5, 7, 3, 6, 0xabcd);
        for format in [WeightFormat::Bp32, WeightFormat::F32, WeightFormat::Bp64] {
            let mut be = NativeBackend::from_weights(&w, format).unwrap();
            assert_eq!(be.dims(), (5, 3));
            // Whole golden batch at once + one-row batches: same bits.
            let rows = w.batch;
            let got = be.run(&w.golden_x, rows).unwrap().to_vec();
            for g in 0..rows {
                let want = reference_forward(&w, format, &w.golden_x[g * 5..(g + 1) * 5]);
                let got_row = &got[g * 3..(g + 1) * 3];
                assert_eq!(
                    got_row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{} row {g}",
                    format.name()
                );
                let one = be.run(&w.golden_x[g * 5..(g + 1) * 5], 1).unwrap();
                assert_eq!(
                    one.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{} single-row {g}",
                    format.name()
                );
            }
        }
    }

    #[test]
    fn native_backend_bp32_matches_golden_logits() {
        // golden_logits_bposit in the synthetic model *is* the reference
        // forward on golden_x — the backend must reproduce it exactly.
        let w = synth_weights(6, 9, 4, 3, 0x1234);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        let got = be.run(&w.golden_x, w.batch).unwrap();
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            w.golden_logits_bposit.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn native_backend_rejects_bad_shapes() {
        let w = synth_weights(4, 4, 2, 2, 1);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        assert!(be.run(&[0.0; 7], 2).is_err());
        let mut bad = w.clone();
        bad.w1_bits.pop();
        assert!(NativeBackend::from_weights(&bad, WeightFormat::Bp32).is_err());
        let mut bad2 = w.clone();
        bad2.b1.pop();
        assert!(NativeBackend::from_weights(&bad2, WeightFormat::F32).is_err());
    }

    #[test]
    fn run_traced_is_bit_identical_and_attributes_stages() {
        let w = synth_weights(6, 9, 4, 5, 0x7ace);
        for format in [WeightFormat::Bp32, WeightFormat::F32, WeightFormat::Bp64] {
            let mut be = NativeBackend::from_weights(&w, format).unwrap();
            let plain = be.run(&w.golden_x, w.batch).unwrap().to_vec();
            let mut timer = StageTimer::default();
            let traced = be.run_traced(&w.golden_x, w.batch, &mut timer).unwrap().to_vec();
            assert_eq!(
                plain.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                traced.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{}: tracing must not change logits",
                format.name()
            );
            // The native backend attributes Staging/Execute/Readout and
            // nothing else; Execute dominates the layer pipeline.
            assert!(timer.get(Stage::Execute) > 0, "{}", format.name());
            assert_eq!(timer.get(Stage::QueueWait), 0);
            assert_eq!(timer.get(Stage::InputCodec), 0);
            assert_eq!(
                timer.sum(),
                timer.get(Stage::Staging) + timer.get(Stage::Execute) + timer.get(Stage::Readout),
                "{}: only the three backend stages may be charged",
                format.name()
            );
        }
    }

    #[test]
    fn stage_inputs_timed_matches_untimed_bitwise() {
        let xs: Vec<f32> = (0..4096).map(|i| (i as f32 - 2048.0) * 0.173).collect();
        let mut a = xs.clone();
        let mut b = xs.clone();
        stage_inputs_in_place(WeightFormat::Bp32, &mut a);
        let ns = stage_inputs_in_place_timed(WeightFormat::Bp32, &mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(ns > 0, "quantizing formats must report worker time");
        let mut c = xs.clone();
        assert_eq!(stage_inputs_in_place_timed(WeightFormat::F32, &mut c), 0);
        assert_eq!(
            c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "identity formats stay identities under timing"
        );
    }

    fn bits32(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn certify_contains_served_logits_all_formats() {
        let w = synth_weights(6, 9, 4, 5, 0x5ee5);
        for format in [WeightFormat::Bp32, WeightFormat::F32, WeightFormat::Bp64] {
            let mut be = NativeBackend::from_weights(&w, format).unwrap();
            for g in 0..w.batch {
                let raw = &w.golden_x[g * 6..(g + 1) * 6];
                let staged = stage_inputs(format, raw);
                let served = be.run(&staged, 1).unwrap().to_vec();
                let rep = be.certify(FeatureRow::F32(raw), &served).unwrap();
                assert!(
                    !rep.violation,
                    "{} row {g}: served logit escaped its certified bound",
                    format.name()
                );
                assert!(
                    rep.max_width.is_finite() && rep.max_width > 0.0,
                    "{} row {g}: width {} not finite-positive",
                    format.name(),
                    rep.max_width
                );
                assert!(rep.mean_width > 0.0 && rep.mean_width <= rep.max_width);
            }
            // Shape mismatches certify to None, not a bogus report.
            assert!(be.certify(FeatureRow::F32(&[0.0; 3]), &[0.0; 4]).is_none());
        }
    }

    #[test]
    fn certify_off_grid_inputs_have_nontrivial_hulls_and_contain() {
        // Off the 1/64 grid the bp32 input roundtrip genuinely moves
        // values, so the hulls (and the certified widths) are nonzero.
        let w = synth_weights(5, 8, 3, 2, 0xbead);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..25 {
            let raw: Vec<f32> = (0..5).map(|_| (rng.f64() * 2.0 - 1.0) as f32 * 1.7).collect();
            let staged = stage_inputs(WeightFormat::Bp32, &raw);
            let served = be.run(&staged, 1).unwrap().to_vec();
            let rep = be.certify(FeatureRow::F32(&raw), &served).unwrap();
            assert!(!rep.violation);
            assert!(rep.max_width.is_finite() && rep.max_width > 0.0);
        }
    }

    #[test]
    fn injected_shrunk_bounds_report_violation() {
        let w = synth_weights(4, 6, 2, 1, 3);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        let raw = w.golden_x[..4].to_vec();
        // Golden features are grid-exact, so staging is the identity.
        let served = be.run(&raw, 1).unwrap().to_vec();
        assert!(!be.certify(FeatureRow::F32(&raw), &served).unwrap().violation);
        be.inject_certify_violation(true);
        assert!(be.certify(FeatureRow::F32(&raw), &served).unwrap().violation);
        be.inject_certify_violation(false);
        assert!(!be.certify(FeatureRow::F32(&raw), &served).unwrap().violation);
    }

    #[test]
    fn run64_matches_reference64_and_widened_run_bitwise() {
        let w = synth_weights(5, 7, 3, 4, 0x64);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp64).unwrap();
        assert!(be.supports_f64_activations());
        // f32-exact activations: the widened f64 staging must reproduce
        // the f32 entry point bit-for-bit.
        let x64: Vec<f64> = w.golden_x.iter().map(|&v| v as f64).collect();
        let via32 = be.run(&w.golden_x, w.batch).unwrap().to_vec();
        let via64 = be.run64(&x64, w.batch).unwrap().to_vec();
        assert_eq!(bits32(&via32), bits32(&via64));
        // Genuinely-64-bit activations against the f64 reference.
        let mut rng = Rng::new(9);
        let y64: Vec<f64> = (0..w.batch * 5).map(|_| (rng.f64() - 0.5) * 3.0).collect();
        let got = be.run64(&y64, w.batch).unwrap().to_vec();
        for g in 0..w.batch {
            let want = reference_forward64(&w, &y64[g * 5..(g + 1) * 5]);
            assert_eq!(bits32(&got[g * 3..(g + 1) * 3]), bits32(&want), "row {g}");
        }
        assert!(be.run64(&y64[..7], 1).is_err(), "bad shape must err");
        // 32-bit tiers refuse f64 staging.
        let mut be32 = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        assert!(!be32.supports_f64_activations());
        assert!(be32.run64(&x64, w.batch).is_err());
    }

    #[test]
    fn certify_bp64_checks_through_f32_readout() {
        let w = synth_weights(5, 7, 3, 2, 0x99);
        let mut be = NativeBackend::from_weights(&w, WeightFormat::Bp64).unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..10 {
            let raw: Vec<f64> = (0..5).map(|_| (rng.f64() - 0.5) * 2.0).collect();
            let served = be.run64(&raw, 1).unwrap().to_vec();
            let rep = be.certify(FeatureRow::F64(&raw), &served).unwrap();
            assert!(!rep.violation, "f32 readout of the f64 logit escaped its bound");
            assert!(rep.max_width.is_finite() && rep.max_width > 0.0);
        }
        assert_eq!(FeatureRow::F64(&[1.0, 2.0]).len(), 2);
        assert!(!FeatureRow::F32(&[1.0]).is_empty());
    }

    #[test]
    fn weight_cache_reused_across_backend_loads() {
        let w = synth_weights(3, 5, 2, 2, 0xcafe);
        let _first = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        let (h0, _m0) = quantizer::weight_cache_stats();
        let _second = NativeBackend::from_weights(&w, WeightFormat::Bp32).unwrap();
        let (h1, _m1) = quantizer::weight_cache_stats();
        assert!(h1 >= h0 + 2, "second load must hit the cache for both layers ({h0} → {h1})");
    }
}
