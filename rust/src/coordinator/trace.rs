//! Request tracing + log-bucketed histograms: the serving stack's
//! observability core, zero external dependencies.
//!
//! Three pieces, all cheap enough for the hot path:
//!
//! - **[`StageTimer`]** — a plain-`u64` per-stage accumulator (no
//!   atomics, `Copy`) threaded through the HTTP listener, the batching
//!   worker, and the backend so every request can report where its
//!   wall time went: accept → parse → queue-wait → staging →
//!   input-codec → execute → readout → serialize → write.
//! - **[`SpanRecord`] + [`Tracer`]** — every `/infer` request gets a
//!   process-unique `u64` trace id ([`next_trace_id`]) and a completed
//!   span; each executed batch gets a *batch span* linking its member
//!   trace ids. Completed spans land in a fixed-capacity ring buffer
//!   (one tiny `Mutex` per slot — writers only contend when they hash
//!   to the same slot, and never block the serving path for longer
//!   than one ~100-byte store). `GET /debug/tracez` renders the ring
//!   as JSON, filterable by `?min_us=` / `?limit=`.
//! - **[`LogHistogram`]** — power-of-2-bucketed `AtomicU64` arrays for
//!   end-to-end latency, queue wait, and per-batch codec/execute time:
//!   allocation-free, wait-free `record` (three relaxed `fetch_add`s),
//!   rendered in Prometheus `_bucket`/`_sum`/`_count` form by
//!   [`HistSnapshot::render_into`].
//!
//! Spans are only *recorded* when tracing is enabled
//! (`ServerConfig::tracing`); the histograms and counters in
//! [`super::metrics`] stay on either way. Nothing here touches the
//! numeric path — observability never changes logits (the integration
//! tests gate on bit-identity with tracing on and off).
//!
//! Request spans are pushed by whoever completes the request: the HTTP
//! layer for `/infer` (so serialize/write are included), the server's
//! `try_infer` for in-process callers. `infer_async` submissions appear
//! in their batch span's member list but get no request span of their
//! own — there is no single completion point to stamp.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of request stages a [`StageTimer`] tracks.
pub const STAGE_COUNT: usize = 9;

/// Spans retained by a default [`Tracer`]: enough to hold several
/// seconds of traffic at demo rates, small enough (~64 KiB) to sit in
/// every server.
pub const TRACE_RING_CAP: usize = 512;

/// One stage of the request path, in request order. HTTP-side stages
/// (`Accept`, `Parse`, `Serialize`, `Write`) are zero for in-process
/// requests; the middle five are measured by the batching worker and
/// the native backend and are shared by every member of a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Reading the request head + body off the socket.
    Accept = 0,
    /// JSON parse + feature extraction.
    Parse = 1,
    /// Submission → the worker seals the batch (includes batch fill
    /// wait, so per-member values differ within one batch).
    QueueWait = 2,
    /// Row copies into the staged batch + transpose into tier layout.
    Staging = 3,
    /// b-posit roundtrip quantization of the staged batch.
    InputCodec = 4,
    /// GEMM + bias/ReLU layers.
    Execute = 5,
    /// Transposing logits back request-major.
    Readout = 6,
    /// Formatting the JSON response body.
    Serialize = 7,
    /// Writing the response bytes to the socket.
    Write = 8,
}

impl Stage {
    /// All stages in request order.
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Accept,
        Stage::Parse,
        Stage::QueueWait,
        Stage::Staging,
        Stage::InputCodec,
        Stage::Execute,
        Stage::Readout,
        Stage::Serialize,
        Stage::Write,
    ];

    /// JSON key for this stage's nanosecond field in `/debug/tracez`.
    pub fn key(self) -> &'static str {
        match self {
            Stage::Accept => "accept_ns",
            Stage::Parse => "parse_ns",
            Stage::QueueWait => "queue_wait_ns",
            Stage::Staging => "staging_ns",
            Stage::InputCodec => "input_codec_ns",
            Stage::Execute => "execute_ns",
            Stage::Readout => "readout_ns",
            Stage::Serialize => "serialize_ns",
            Stage::Write => "write_ns",
        }
    }
}

/// Per-stage nanosecond accumulator: plain `u64`s, `Copy`, no atomics —
/// each thread accumulates into its own timer and timers are merged
/// per batch, so nothing synchronizes inside lane loops.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTimer {
    ns: [u64; STAGE_COUNT],
}

impl StageTimer {
    /// Add `ns` nanoseconds to `stage` (accumulates).
    pub fn add(&mut self, stage: Stage, ns: u64) {
        self.ns[stage as usize] += ns;
    }

    /// Add a [`Duration`] to `stage`.
    pub fn add_duration(&mut self, stage: Stage, d: Duration) {
        self.add(stage, d.as_nanos() as u64);
    }

    /// Accumulated nanoseconds for `stage`.
    pub fn get(&self, stage: Stage) -> u64 {
        self.ns[stage as usize]
    }

    /// Merge another timer in (stage-wise sum) — how per-batch worker
    /// timings fan out into each member's span.
    pub fn merge(&mut self, other: &StageTimer) {
        for i in 0..STAGE_COUNT {
            self.ns[i] += other.ns[i];
        }
    }

    /// Total nanoseconds across all stages.
    pub fn sum(&self) -> u64 {
        self.ns.iter().sum()
    }

    /// Sum over the server-side stages (queue-wait through readout) —
    /// the portion covered by a response's recorded latency.
    pub fn server_sum(&self) -> u64 {
        self.get(Stage::QueueWait)
            + self.get(Stage::Staging)
            + self.get(Stage::InputCodec)
            + self.get(Stage::Execute)
            + self.get(Stage::Readout)
    }
}

/// What a span describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One `/infer` (or in-process) request, end to end.
    Request,
    /// One executed batch, linking its member request trace ids.
    Batch,
}

/// A completed span: one ring-buffer entry, rendered by `/debug/tracez`.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Process-unique id (echoed to `/infer` clients for correlation).
    pub trace_id: u64,
    pub kind: SpanKind,
    /// The batch span that executed this request (batch spans point at
    /// themselves), correlating request spans with their batch.
    pub batch_id: u64,
    /// Independently measured wall time: for request spans the
    /// connection-to-written-response total (or the recorded latency
    /// for in-process requests); for batch spans the stage sum. The
    /// per-stage breakdown must account for this within a few percent.
    pub total_ns: u64,
    /// Rows in the executing batch.
    pub rows: u32,
    /// Batch spans: member request trace ids (empty on request spans).
    pub members: Vec<u64>,
    /// Batch spans: summed per-thread worker ns inside the sharded
    /// codec (can exceed the wall-clock `input_codec_ns` when shards
    /// run in parallel; 0 when the format does not quantize inputs).
    pub codec_worker_ns: u64,
    pub stages: StageTimer,
}

impl SpanRecord {
    /// A request span. `total_ns` is the recorded latency; HTTP callers
    /// re-stamp it with the full connection wall time after the write.
    pub fn request(trace_id: u64, batch_id: u64, rows: u32, total_ns: u64, stages: StageTimer) -> SpanRecord {
        SpanRecord {
            trace_id,
            kind: SpanKind::Request,
            batch_id,
            total_ns,
            rows,
            members: Vec::new(),
            codec_worker_ns: 0,
            stages,
        }
    }

    /// A batch span linking its member request trace ids.
    pub fn batch(batch_id: u64, members: Vec<u64>, rows: u32, stages: StageTimer, codec_worker_ns: u64) -> SpanRecord {
        SpanRecord {
            trace_id: batch_id,
            kind: SpanKind::Batch,
            batch_id,
            total_ns: stages.sum(),
            rows,
            members,
            codec_worker_ns,
            stages,
        }
    }

    /// Render as one `/debug/tracez` JSON object.
    pub fn json(&self) -> String {
        let mut s = String::with_capacity(256);
        s.push_str(&format!(
            "{{\"trace_id\":{},\"kind\":\"{}\",\"batch_id\":{},\"total_us\":{},\"total_ns\":{},\"rows\":{}",
            self.trace_id,
            match self.kind {
                SpanKind::Request => "request",
                SpanKind::Batch => "batch",
            },
            self.batch_id,
            self.total_ns / 1_000,
            self.total_ns,
            self.rows
        ));
        if self.kind == SpanKind::Batch {
            let ids: Vec<String> = self.members.iter().map(|m| m.to_string()).collect();
            s.push_str(&format!(
                ",\"members\":[{}],\"codec_worker_ns\":{}",
                ids.join(","),
                self.codec_worker_ns
            ));
        }
        s.push_str(",\"stages\":{");
        for (i, st) in Stage::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{}\":{}", st.key(), self.stages.get(*st)));
        }
        s.push_str("}}");
        s
    }
}

/// Process-wide trace/batch id allocator: ids start at 1 (0 means "not
/// traced") and are unique across every server in the process.
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Fixed-capacity span ring: `head` claims a slot with one relaxed
/// `fetch_add`, then the writer takes that slot's own tiny `Mutex` for
/// the store. Concurrent writers only contend when they wrap onto the
/// same slot; a torn span is impossible and readers never block the
/// whole ring.
struct TraceRing {
    slots: Vec<Mutex<Option<SpanRecord>>>,
    head: AtomicU64,
}

impl TraceRing {
    fn new(capacity: usize) -> TraceRing {
        let slots = (0..capacity.max(1)).map(|_| Mutex::new(None)).collect();
        TraceRing { slots, head: AtomicU64::new(0) }
    }

    fn push(&self, span: SpanRecord) {
        let i = (self.head.fetch_add(1, Ordering::Relaxed) as usize) % self.slots.len();
        *self.slots[i].lock().unwrap() = Some(span);
    }

    /// Newest-first snapshot (ordering is approximate while writers are
    /// concurrently wrapping — fine for a debug endpoint).
    fn snapshot(&self, min_ns: u64, limit: usize) -> Vec<SpanRecord> {
        let cap = self.slots.len();
        let head = self.head.load(Ordering::Relaxed) as usize;
        let mut out = Vec::new();
        // Walk backwards from the most recently claimed slot.
        for back in 1..=cap {
            if out.len() >= limit {
                break;
            }
            let idx = (head.wrapping_add(cap) - back) % cap;
            let slot = self.slots[idx].lock().unwrap();
            if let Some(span) = slot.as_ref() {
                if span.total_ns >= min_ns {
                    out.push(span.clone());
                }
            }
        }
        out
    }
}

/// The per-server span sink: a [`TraceRing`] plus the enabled flag from
/// `ServerConfig::tracing`. `push` is a no-op when disabled, so callers
/// never branch.
pub struct Tracer {
    enabled: bool,
    ring: TraceRing,
    /// Spans ever pushed (monotone; the ring keeps the last N).
    pushed: AtomicU64,
}

impl Tracer {
    /// A tracer with the default ring capacity ([`TRACE_RING_CAP`]).
    pub fn new(enabled: bool) -> Tracer {
        Tracer::with_capacity(enabled, TRACE_RING_CAP)
    }

    /// A tracer with an explicit ring capacity (tests exercise small
    /// rings to force wraparound).
    pub fn with_capacity(enabled: bool, capacity: usize) -> Tracer {
        Tracer { enabled, ring: TraceRing::new(capacity), pushed: AtomicU64::new(0) }
    }

    /// Whether spans are recorded at all.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Retain a completed span (no-op when tracing is disabled).
    pub fn push(&self, span: SpanRecord) {
        if !self.enabled {
            return;
        }
        self.pushed.fetch_add(1, Ordering::Relaxed);
        self.ring.push(span);
    }

    /// Spans ever pushed (monotone).
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    /// Newest-first spans with `total_ns ≥ min_us·1000`, at most `limit`.
    pub fn spans(&self, min_us: u64, limit: usize) -> Vec<SpanRecord> {
        self.ring.snapshot(min_us.saturating_mul(1_000), limit)
    }

    /// The `/debug/tracez` body.
    pub fn render_json(&self, min_us: u64, limit: usize) -> String {
        let spans = self.spans(min_us, limit);
        let mut s = String::with_capacity(64 + 256 * spans.len());
        s.push_str(&format!(
            "{{\"enabled\":{},\"capacity\":{},\"pushed\":{},\"count\":{},\"spans\":[",
            self.enabled,
            self.ring.slots.len(),
            self.pushed(),
            spans.len()
        ));
        for (i, span) in spans.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&span.json());
        }
        s.push_str("]}");
        s
    }
}

/// Buckets below the `+Inf` overflow slot: upper bounds `2^0 … 2^39`
/// (in the recorded unit — µs histograms top out at ~6.4 days, ns ones
/// at ~9 minutes before overflowing into `+Inf`).
pub const HIST_BUCKETS: usize = 40;

/// Power-of-2 log-bucketed histogram: `record` is allocation-free and
/// wait-free (three relaxed `fetch_add`s), so it sits directly on the
/// request path. Bucket *i* counts values `v ≤ 2^i` not already counted
/// by a smaller bucket; a value exactly on a power of 2 lands in the
/// bucket whose upper bound equals it (Prometheus `le` semantics).
pub struct LogHistogram {
    buckets: [AtomicU64; HIST_BUCKETS + 1],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    /// Bucket index for a value: 0 for `v ≤ 1`, else `⌈log2 v⌉`, capped
    /// at the `+Inf` slot.
    pub fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((u64::BITS - (v - 1).leading_zeros()) as usize).min(HIST_BUCKETS)
        }
    }

    /// Upper bound (`le` label) of bucket `i`; `None` for the `+Inf`
    /// overflow slot.
    pub fn bucket_le(i: usize) -> Option<u64> {
        (i < HIST_BUCKETS).then(|| 1u64 << i)
    }

    /// Record one observation (wait-free).
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy (per-bucket raw counts, not yet cumulative).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time histogram view, renderable as Prometheus
/// `_bucket`/`_sum`/`_count` lines.
#[derive(Clone, Debug, Default)]
pub struct HistSnapshot {
    /// Raw per-bucket counts, `HIST_BUCKETS + 1` entries (last = `+Inf`).
    pub buckets: Vec<u64>,
    pub sum: u64,
    pub count: u64,
}

impl HistSnapshot {
    /// Append Prometheus histogram exposition lines for `name` (which
    /// should carry the unit suffix, e.g. `positron_queue_wait_us`).
    pub fn render_into(&self, out: &mut String, name: &str) {
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            match LogHistogram::bucket_le(i) {
                Some(le) => out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n")),
                None => out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n")),
            }
        }
        out.push_str(&format!("{name}_sum {}\n", self.sum));
        out.push_str(&format!("{name}_count {}\n", self.count));
    }

    /// Upper-bound quantile estimate: the `le` of the first bucket whose
    /// cumulative count reaches `p·count` (0 when empty, `u64::MAX` if
    /// the quantile falls in the `+Inf` overflow slot).
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p * self.count as f64).ceil()).max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return LogHistogram::bucket_le(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_land_on_powers_of_two() {
        // The satellite contract: a value exactly on a power of 2 lands
        // in the bucket whose upper bound equals it.
        assert_eq!(LogHistogram::bucket_index(0), 0);
        assert_eq!(LogHistogram::bucket_index(1), 0);
        for i in 1..HIST_BUCKETS {
            let v = 1u64 << i;
            assert_eq!(LogHistogram::bucket_index(v), i, "v = 2^{i}");
            assert_eq!(LogHistogram::bucket_index(v + 1), i + 1, "v = 2^{i}+1");
            assert_eq!(LogHistogram::bucket_le(i), Some(v));
        }
        // Values past the largest finite bound overflow into +Inf.
        assert_eq!(LogHistogram::bucket_index(u64::MAX), HIST_BUCKETS);
        assert_eq!(LogHistogram::bucket_le(HIST_BUCKETS), None);
    }

    #[test]
    fn histogram_records_and_renders_cumulative() {
        let h = LogHistogram::default();
        for v in [0u64, 1, 2, 3, 4, 1024] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 1034);
        assert_eq!(s.buckets[0], 2, "0 and 1 share the le=1 bucket");
        assert_eq!(s.buckets[1], 1, "2 lands exactly on le=2");
        assert_eq!(s.buckets[2], 2, "3 and 4 land in le=4");
        assert_eq!(s.buckets[10], 1, "1024 lands exactly on le=1024");
        let mut text = String::new();
        s.render_into(&mut text, "test_hist");
        assert!(text.contains("test_hist_bucket{le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("test_hist_bucket{le=\"2\"} 3\n"), "{text}");
        assert!(text.contains("test_hist_bucket{le=\"4\"} 5\n"), "{text}");
        assert!(text.contains("test_hist_bucket{le=\"+Inf\"} 6\n"), "{text}");
        assert!(text.contains("test_hist_sum 1034\n"), "{text}");
        assert!(text.contains("test_hist_count 6\n"), "{text}");
    }

    #[test]
    fn histogram_quantile_upper_bounds() {
        let h = LogHistogram::default();
        for _ in 0..90 {
            h.record(10); // bucket le=16
        }
        for _ in 0..10 {
            h.record(1000); // bucket le=1024
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 16);
        assert_eq!(s.quantile(0.99), 1024);
        assert_eq!(HistSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn stage_timer_accumulates_and_merges() {
        let mut a = StageTimer::default();
        a.add(Stage::QueueWait, 100);
        a.add(Stage::QueueWait, 50);
        a.add_duration(Stage::Execute, Duration::from_nanos(200));
        let mut b = StageTimer::default();
        b.add(Stage::Execute, 300);
        b.add(Stage::Staging, 25);
        a.merge(&b);
        assert_eq!(a.get(Stage::QueueWait), 150);
        assert_eq!(a.get(Stage::Execute), 500);
        assert_eq!(a.get(Stage::Staging), 25);
        assert_eq!(a.sum(), 675);
        assert_eq!(a.server_sum(), 675, "all recorded stages are server-side here");
    }

    #[test]
    fn span_json_carries_every_stage_key() {
        let mut st = StageTimer::default();
        st.add(Stage::Execute, 42_000);
        let span = SpanRecord::request(7, 9, 3, 50_000, st);
        let j = span.json();
        for stage in Stage::ALL {
            assert!(j.contains(stage.key()), "{j} missing {}", stage.key());
        }
        assert!(j.contains("\"trace_id\":7"), "{j}");
        assert!(j.contains("\"batch_id\":9"), "{j}");
        assert!(j.contains("\"total_us\":50"), "{j}");
        assert!(j.contains("\"kind\":\"request\""), "{j}");
        assert!(!j.contains("members"), "request spans carry no member list: {j}");
        let b = SpanRecord::batch(9, vec![7, 8], 2, st, 1234);
        let bj = b.json();
        assert!(bj.contains("\"members\":[7,8]"), "{bj}");
        assert!(bj.contains("\"codec_worker_ns\":1234"), "{bj}");
        assert!(bj.contains("\"kind\":\"batch\""), "{bj}");
        assert_eq!(b.total_ns, st.sum());
        crate::json::Json::parse(&bj).expect("span JSON must parse");
    }

    #[test]
    fn ring_retains_newest_and_wraps_single_writer() {
        let t = Tracer::with_capacity(true, 8);
        for id in 1..=11u64 {
            t.push(SpanRecord::request(id, id, 1, id * 1_000_000, StageTimer::default()));
        }
        assert_eq!(t.pushed(), 11);
        let spans = t.spans(0, usize::MAX);
        assert_eq!(spans.len(), 8, "ring holds exactly its capacity");
        let ids: Vec<u64> = spans.iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, vec![11, 10, 9, 8, 7, 6, 5, 4], "newest first, oldest evicted");
        // min_us filter (total_ns = id ms): only ids ≥ 9 pass 8500 µs.
        let slow = t.spans(8_500, usize::MAX);
        assert_eq!(slow.iter().map(|s| s.trace_id).collect::<Vec<_>>(), vec![11, 10, 9]);
        // limit caps the newest-first walk.
        assert_eq!(t.spans(0, 2).len(), 2);
    }

    #[test]
    fn ring_wraparound_under_concurrent_writers() {
        // 4 writers × 64 spans through an 8-slot ring: the ring must end
        // up full with 8 distinct, untorn spans, each one that was
        // actually pushed (total_ns mirrors the trace id so a torn write
        // would be visible).
        let t = Tracer::with_capacity(true, 8);
        std::thread::scope(|s| {
            for w in 0..4u64 {
                let tr = &t;
                s.spawn(move || {
                    for i in 0..64u64 {
                        let id = w * 1_000 + i + 1;
                        let mut st = StageTimer::default();
                        st.add(Stage::Execute, id);
                        tr.push(SpanRecord::request(id, id, 1, id, st));
                    }
                });
            }
        });
        assert_eq!(t.pushed(), 256);
        let spans = t.spans(0, usize::MAX);
        assert_eq!(spans.len(), 8);
        let mut seen = std::collections::HashSet::new();
        for sp in &spans {
            assert!(seen.insert(sp.trace_id), "duplicate span {}", sp.trace_id);
            assert_eq!(sp.total_ns, sp.trace_id, "torn span {}", sp.trace_id);
            assert_eq!(sp.stages.get(Stage::Execute), sp.trace_id);
            let (w, i) = (sp.trace_id / 1_000, sp.trace_id % 1_000);
            assert!(w < 4 && (1..=64).contains(&i), "span {} was never pushed", sp.trace_id);
        }
    }

    #[test]
    fn disabled_tracer_drops_spans() {
        let t = Tracer::with_capacity(false, 8);
        t.push(SpanRecord::request(1, 1, 1, 10, StageTimer::default()));
        assert!(!t.enabled());
        assert_eq!(t.pushed(), 0);
        assert!(t.spans(0, usize::MAX).is_empty());
        let j = t.render_json(0, 16);
        assert!(j.contains("\"enabled\":false"), "{j}");
        assert!(j.contains("\"spans\":[]"), "{j}");
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(a > 0 && b > a);
    }

    #[test]
    fn render_json_parses_and_filters() {
        let t = Tracer::with_capacity(true, 8);
        let mut st = StageTimer::default();
        st.add(Stage::Execute, 2_000_000);
        t.push(SpanRecord::batch(3, vec![1, 2], 2, st, 0));
        let j = t.render_json(0, 16);
        let parsed = crate::json::Json::parse(&j).expect("tracez JSON must parse");
        assert_eq!(parsed.get("count").and_then(|c| c.as_usize()), Some(1));
        assert_eq!(parsed.get("spans").and_then(|s| s.as_arr()).map(|a| a.len()), Some(1));
        // 2 ms span filtered out by min_us = 3000.
        let none = t.render_json(3_000, 16);
        assert!(none.contains("\"count\":0"), "{none}");
    }
}
