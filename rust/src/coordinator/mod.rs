//! L3 coordinator: the serving loop around the model.
//!
//! The paper's contribution lives in the format (L1/L2 + the hw designs),
//! so L3 is a deliberately thin but production-shaped driver: a bounded
//! request queue, a dynamic batcher (max-batch / max-wait), b-posit
//! quantization of inputs on the hot path via the Rust codec, pluggable
//! execution backends, per-request deadlines, and latency/throughput
//! metrics behind a real HTTP listener.
//!
//! - [`backend`] — the [`InferenceBackend`] trait with two impls: the
//!   default **native** executor (dense layers on the blocked
//!   quantized-weight GEMM, weights encoded once through a content-hash
//!   cache; no libxla) and the PJRT/XLA executor (`runtime` feature).
//! - [`server`] — the batching worker + typed client errors
//!   ([`InferError`] / [`ServeError`]): queue-full backpressure,
//!   deadline expiry, and explicit per-request batch-failure answers.
//! - [`http`] — zero-dependency HTTP/1.1 listener: `GET /metrics`
//!   (Prometheus-style), `GET /healthz`, `POST /infer`.
//! - [`metrics`] — counters + bounded-reservoir latency quantiles.
//! - [`quantizer`] — the f32⇄b-posit batch codec tiers and the
//!   process-wide quantized-weight cache.

pub mod backend;
pub mod http;
pub mod metrics;
pub mod quantizer;
pub mod server;

pub use backend::{BackendKind, InferenceBackend, NativeBackend, PjrtBackend, WeightFormat};
pub use http::HttpServer;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{InferError, InferenceServer, Response, ServeError, ServerConfig};
