//! L3 coordinator: the serving loop around the AOT-compiled model.
//!
//! The paper's contribution lives in the format (L1/L2 + the hw designs),
//! so L3 is a deliberately thin but production-shaped driver: a bounded
//! request queue, a dynamic batcher (max-batch / max-wait), b-posit
//! quantization of inputs on the hot path via the Rust codec, PJRT
//! execution, and latency/throughput metrics.

pub mod metrics;
pub mod quantizer;
pub mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{InferenceServer, Response, ServerConfig};
