//! L3 coordinator: the serving loop around the model.
//!
//! The paper's contribution lives in the format (L1/L2 + the hw designs),
//! so L3 is a deliberately thin but production-shaped driver: a bounded
//! request queue, a dynamic batcher (max-batch / max-wait), b-posit
//! quantization of inputs on the hot path via the Rust codec, pluggable
//! execution backends, per-request deadlines, and latency/throughput
//! metrics behind a real HTTP listener.
//!
//! - [`backend`] — the [`InferenceBackend`] trait with two impls: the
//!   default **native** executor (dense layers on the blocked
//!   quantized-weight GEMM, weights encoded once through a content-hash
//!   cache; no libxla) and the PJRT/XLA executor (`runtime` feature).
//! - [`server`] — the batching worker + typed client errors
//!   ([`InferError`] / [`ServeError`]): queue-full backpressure,
//!   deadline expiry, and explicit per-request batch-failure answers.
//! - [`http`] — zero-dependency event-driven HTTP/1.1 listener
//!   (nonblocking accept + epoll/poll readiness loop, keep-alive,
//!   pipelining, admission control): `POST /v1/infer/<model>` routed
//!   through a [`ModelRegistry`], `GET /v1/models`, legacy `POST
//!   /infer`, `GET /metrics` (Prometheus-style), `GET /healthz`,
//!   `GET /debug/tracez` (the span ring, `?min_us=`/`?limit=`), typed
//!   [`ApiError`] JSON error bodies (see `docs/HTTP_API.md`).
//! - [`metrics`] — counters, bounded-reservoir latency quantiles, and
//!   power-of-2 log-bucketed histograms (latency, queue wait, codec,
//!   execute) in Prometheus `_bucket`/`_sum`/`_count` form.
//! - [`trace`] — request/batch spans with per-stage nanosecond timings
//!   ([`StageTimer`], accept → … → write), the fixed-capacity span ring
//!   behind `/debug/tracez`, and the histogram primitive. Observability
//!   never changes logits (bit-identical with tracing on or off).
//! - [`quantizer`] — the f32⇄b-posit batch codec tiers and the
//!   process-wide quantized-weight cache.

pub mod backend;
pub mod http;
pub mod metrics;
pub mod quantizer;
pub mod server;
pub mod trace;

pub use backend::{BackendKind, InferenceBackend, NativeBackend, PjrtBackend, WeightFormat};
pub use http::{ApiError, HttpClient, HttpResponse, HttpServer};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{
    InferError, InferenceServer, ModelEntry, ModelRegistry, Notify, Pending, Response, ServeError,
    ServerConfig, ServerConfigBuilder,
};
pub use trace::{SpanRecord, Stage, StageTimer, Tracer};
