//! float ⇄ b-posit tensor quantization on the request path (Rust codec,
//! no Python) — **one generic family** over both serving widths. This is
//! the hot path profiled in EXPERIMENTS.md §Perf.
//!
//! The 32- and 64-bit tiers share every function: the encode direction is
//! generic over [`LaneElem`] (`quantize(&[f32])` → `Vec<i32>`,
//! `quantize(&[f64])` → `Vec<i64>`), and the decode direction is generic
//! over [`LaneSigned`] so the width is inferred from the *bit-pattern*
//! argument (`dequantize(&[i32])` → `Vec<f32>` with no turbofish). The
//! historical `quantize64*` names are thin aliases (docs/API.md).
//!
//! Three codec tiers, fastest first:
//! - **Vector** (the lane engine in [`crate::vector::lane`], sharded
//!   across worker threads by [`crate::vector::parallel`]): branch-free
//!   8-lane batched encode/decode — every slice-level entry point here
//!   routes through it, and the `_into`/`_in_place` variants reuse
//!   caller buffers so the steady-state serving path performs zero
//!   per-request heap allocation. Batches big enough to amortize a
//!   fork-join (see [`parallel::CODEC_MIN_SHARD`]) are split into
//!   contiguous blocks over up to `PALLAS_THREADS` workers; results are
//!   bit-identical to serial for any thread count, so sharding is
//!   transparent to callers.
//! - **Scalar fast path** ([`fast_bp32_encode`]/[`fast_bp32_decode`]):
//!   the specialized branch-light ⟨32,6,5⟩ pair, kept as an
//!   *independent implementation* the lane codec is tested against
//!   (bit-identical on every input).
//! - **General codec** ([`quantize_one_general`]): the exact
//!   spec-driven reference via the 128-bit BitStream serializer — the
//!   parity oracle and the §Perf "before" baseline, at either width.
//!
//! # Contract (all tiers, same as the Pallas kernel)
//! - Encode: subnormal inputs quantize to 0 — the float pipeline is
//!   FTZ/DAZ end-to-end. NaN/Inf → NaR.
//! - Decode: results below the float normal range flush to ±0; above it
//!   ±∞; NaR → NaN.

use crate::formats::Decoded;
use crate::vector::lane::{LaneElem, LaneSigned};
use crate::vector::parallel;

/// Quantize a float slice to serving-format words (as signed bit
/// patterns) through the vector codec, at either width.
pub fn quantize<E: LaneElem>(xs: &[E]) -> Vec<E::Signed> {
    let mut out = Vec::new();
    quantize_into(xs, &mut out);
    out
}

/// Quantize into a reused buffer (cleared + refilled; no allocation once
/// the buffer has grown to the steady-state batch size). The lane encoder
/// is branch-free, so each shard compiles to the same straight-line inner
/// loop as the chunked drivers in the lane engine; batches past the
/// fork-join threshold are sharded across worker threads (bit-identical
/// results).
pub fn quantize_into<E: LaneElem>(xs: &[E], out: &mut Vec<E::Signed>) {
    // resize alone (no clear) keeps the steady-state same-size call from
    // re-zeroing a buffer the codec is about to overwrite anyway.
    out.resize(xs.len(), Default::default());
    let shards = parallel::auto_shards(xs.len(), parallel::CODEC_MIN_SHARD);
    parallel::for_each_block(shards, &mut out[..], |off, block| {
        for (o, &x) in block.iter_mut().zip(&xs[off..off + block.len()]) {
            *o = E::word_to_signed(E::bp_encode_lane(x));
        }
    });
}

/// Quantize one value (serving-spec lane codec, either width).
#[inline]
pub fn quantize_one<E: LaneElem>(x: E) -> E::Signed {
    E::word_to_signed(E::bp_encode_lane(x))
}

/// Dequantize serving-format words back to floats through the vector
/// codec; the width is inferred from the bit-pattern element type.
pub fn dequantize<S, E>(bits: &[S]) -> Vec<E>
where
    S: LaneSigned<Elem = E>,
    E: LaneElem<Signed = S>,
{
    let mut out = Vec::new();
    dequantize_into(bits, &mut out);
    out
}

/// Dequantize into a reused buffer (sharded past the fork-join threshold).
pub fn dequantize_into<S, E>(bits: &[S], out: &mut Vec<E>)
where
    S: LaneSigned<Elem = E>,
    E: LaneElem<Signed = S>,
{
    out.resize(bits.len(), E::ZERO);
    let shards = parallel::auto_shards(bits.len(), parallel::CODEC_MIN_SHARD);
    parallel::for_each_block(shards, &mut out[..], |off, block| {
        for (o, &b) in block.iter_mut().zip(&bits[off..off + block.len()]) {
            *o = E::bp_decode_lane(b.to_word());
        }
    });
}

/// Dequantize one word (serving-spec lane codec, width inferred from the
/// bit-pattern type).
#[inline]
pub fn dequantize_one<S, E>(bits: S) -> E
where
    S: LaneSigned<Elem = E>,
    E: LaneElem<Signed = S>,
{
    E::bp_decode_lane(bits.to_word())
}

/// Reference (general-codec) quantize — kept for parity tests and as the
/// §Perf "before" baseline, at either width.
///
/// Applies the same FTZ contract as the fast path (subnormal inputs
/// quantize to 0), so general/fast parity is exact on *every* input, not
/// just normals.
#[inline]
pub fn quantize_one_general<E: LaneElem>(x: E) -> E::Signed {
    if x.abs() < E::MIN_POS {
        // Covers ±0 and all subnormals; NaN compares false and falls through.
        return E::word_to_signed(E::word_from_u64(0));
    }
    E::word_to_signed(E::word_from_u64(E::BP.encode(&Decoded::from_f64(x.to_f64()))))
}

/// Reference (general-codec) dequantize, with the same float-facing
/// contract as the fast path: sub-normal-range magnitudes flush to ±0
/// (the plain cast would keep them as subnormals), out-of-range
/// magnitudes become ±∞ via the cast.
#[inline]
pub fn dequantize_one_general<S, E>(bits: S) -> E
where
    S: LaneSigned<Elem = E>,
    E: LaneElem<Signed = S>,
{
    let v = E::from_f64(E::BP.decode(E::word_to_u64(bits.to_word())).to_f64());
    if v != E::ZERO && v.abs() < E::MIN_POS {
        return if v < E::ZERO { E::from_f64(-0.0) } else { E::ZERO };
    }
    v
}

/// Round a float tensor through the serving b-posit format (quantize +
/// dequantize) — what the server does to inputs so the CPU model sees
/// exactly the values a b-posit datapath would.
pub fn roundtrip<E: LaneElem>(xs: &[E]) -> Vec<E> {
    let mut out = xs.to_vec();
    roundtrip_in_place(&mut out);
    out
}

/// In-place roundtrip over a caller buffer — the server's per-batch path
/// (fused encode+decode, no intermediate buffer, no allocation; sharded
/// across worker threads past the fork-join threshold).
pub fn roundtrip_in_place<E: LaneElem>(xs: &mut [E]) {
    parallel::par_bp_roundtrip_in_place(xs);
}

/// [`roundtrip_in_place`] plus summed per-thread worker nanoseconds (the
/// codec's CPU cost — exceeds wall time when shards run in parallel).
/// Identical shard split, so the output is bit-identical to the untimed
/// path for any thread count.
pub fn roundtrip_in_place_timed<E: LaneElem>(xs: &mut [E]) -> u64 {
    parallel::par_bp_roundtrip_in_place_timed(xs)
}

// ----------------------------------------------------------------------
// Historical 64-bit names — thin aliases over the generic family
// (docs/API.md). Contract notes that are width-specific: in-range f64s
// are *exactly* representable in ⟨64,6,5⟩ (≥ 52 fraction bits at every
// scale), so `quantize64` is lossless on the format's 2^±192 range.
// ----------------------------------------------------------------------

/// Quantize an f64 slice to b-posit64 words (as i64 bit patterns).
pub fn quantize64(xs: &[f64]) -> Vec<i64> {
    quantize(xs)
}

/// Quantize into a reused buffer (sharded past the fork-join threshold).
pub fn quantize64_into(xs: &[f64], out: &mut Vec<i64>) {
    quantize_into(xs, out);
}

/// Quantize one f64 (b-posit64 lane codec).
#[inline]
pub fn quantize64_one(x: f64) -> i64 {
    quantize_one(x)
}

/// Dequantize b-posit64 words back to f64 through the vector codec.
pub fn dequantize64(bits: &[i64]) -> Vec<f64> {
    dequantize(bits)
}

/// Dequantize into a reused buffer (sharded past the fork-join threshold).
pub fn dequantize64_into(bits: &[i64], out: &mut Vec<f64>) {
    dequantize_into(bits, out);
}

/// Dequantize one b-posit64 word.
#[inline]
pub fn dequantize64_one(bits: i64) -> f64 {
    dequantize_one(bits)
}

/// Reference (general-codec) b-posit64 quantize — the parity oracle for
/// the lane path, with the same FTZ contract.
#[inline]
pub fn quantize64_one_general(x: f64) -> i64 {
    quantize_one_general(x)
}

/// Reference (general-codec) b-posit64 dequantize with the f64-facing
/// contract (sub-normal-range magnitudes flush to ±0).
#[inline]
pub fn dequantize64_one_general(bits: i64) -> f64 {
    dequantize_one_general(bits)
}

/// Round an f64 tensor through b-posit64 (quantize + dequantize).
pub fn roundtrip64(xs: &[f64]) -> Vec<f64> {
    roundtrip(xs)
}

/// In-place b-posit64 roundtrip over a caller buffer (fused, sharded).
pub fn roundtrip64_in_place(xs: &mut [f64]) {
    roundtrip_in_place(xs);
}

// ----------------------------------------------------------------------
// Quantized-weight cache: process-wide, keyed by tensor *content* hash
// (FNV-1a over the element bit patterns, salted with a tag string and the
// tensor dims). Serving backends encode/transpose model weights exactly
// once per distinct tensor — reloading the same model, or serving it from
// several servers in one process, reuses the first encoding via `Arc`.
// Zero dependencies: plain `Mutex<HashMap>` (load-time path, not the
// request path).
// ----------------------------------------------------------------------

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A cached encoded-weight tensor (whatever layout the builder produced).
#[derive(Clone)]
pub enum CachedWeights {
    /// u32 posit words (the b-posit32 serving weights).
    U32(Arc<Vec<u32>>),
    /// u64 posit words (the b-posit64 serving weights).
    U64(Arc<Vec<u64>>),
    /// Plain f32 weights (the float baseline).
    F32(Arc<Vec<f32>>),
}

static WEIGHT_CACHE: OnceLock<Mutex<HashMap<u64, CachedWeights>>> = OnceLock::new();
static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Distinct tensors retained at once. A server reloading retrained
/// weights produces a *new* content hash per reload; without a bound the
/// Arc-pinned old encodings would accumulate forever. Eviction is
/// arbitrary-entry (the cache is a dedup, not an LRU — live backends
/// keep their own `Arc`s regardless).
pub const WEIGHT_CACHE_CAP: usize = 64;

fn cache() -> &'static Mutex<HashMap<u64, CachedWeights>> {
    WEIGHT_CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn insert_bounded(m: &mut HashMap<u64, CachedWeights>, key: u64, v: CachedWeights) {
    if m.len() >= WEIGHT_CACHE_CAP && !m.contains_key(&key) {
        if let Some(evict) = m.keys().next().copied() {
            m.remove(&evict);
        }
    }
    m.insert(key, v);
}

/// FNV-1a over a stream of u64 words.
fn fnv1a64(words: impl Iterator<Item = u64>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut step = |w: u64| {
        for byte in w.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for w in words {
        step(w);
    }
    h
}

/// Content key for an i32 bit-pattern tensor (tag + dims + every word).
pub fn tensor_key_i32(tag: &str, rows: usize, cols: usize, bits: &[i32]) -> u64 {
    let head = tag.bytes().map(|b| b as u64).chain([rows as u64, cols as u64]);
    fnv1a64(head.chain(bits.iter().map(|&b| b as u32 as u64)))
}

/// Content key for an f32 tensor (tag + dims + every element's bits).
pub fn tensor_key_f32(tag: &str, rows: usize, cols: usize, xs: &[f32]) -> u64 {
    let head = tag.bytes().map(|b| b as u64).chain([rows as u64, cols as u64]);
    fnv1a64(head.chain(xs.iter().map(|x| x.to_bits() as u64)))
}

// The three typed lookups share one shape: a hit must match the caller's
// layout (a mismatch under the same key is possible only on a hash
// collision across tags and is treated as a miss and overwritten); the
// build runs *outside* the lock — encoding a large tensor can take a
// while, and a racing builder just repeats the same deterministic work.
// One macro, so the protocol can't silently diverge between element types.
macro_rules! cached_weights_fn {
    ($(#[$doc:meta])* $name:ident, $elem:ty, $variant:ident) => {
        $(#[$doc])*
        pub fn $name(key: u64, build: impl FnOnce() -> Vec<$elem>) -> Arc<Vec<$elem>> {
            if let Some(CachedWeights::$variant(a)) = cache().lock().unwrap().get(&key).cloned() {
                // ORDERING: Relaxed — monotone metrics counter, no other
                // memory depends on its value.
                CACHE_HITS.fetch_add(1, Ordering::Relaxed);
                return a;
            }
            // ORDERING: Relaxed — monotone metrics counter (see above).
            CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
            let a = Arc::new(build());
            insert_bounded(&mut cache().lock().unwrap(), key, CachedWeights::$variant(a.clone()));
            a
        }
    };
}

cached_weights_fn!(
    /// Cached u32-word weight tensor (b-posit32 serving weights).
    cached_weights_u32,
    u32,
    U32
);
cached_weights_fn!(
    /// Cached u64-word weight tensor (b-posit64 serving weights).
    cached_weights_u64,
    u64,
    U64
);
cached_weights_fn!(
    /// Cached f32 weight tensor (the float-baseline serving weights).
    cached_weights_f32,
    f32,
    F32
);

/// `(hits, misses)` since process start (monotone; shared by all servers;
/// exported by `/metrics` as `positron_weight_cache_{hits,misses}_total`).
pub fn weight_cache_stats() -> (u64, u64) {
    // ORDERING: Relaxed — scrape-time reads of independent counters; a
    // torn hit/miss pair across a racing insert is fine for metrics.
    (CACHE_HITS.load(Ordering::Relaxed), CACHE_MISSES.load(Ordering::Relaxed))
}

/// Number of distinct cached tensors.
pub fn weight_cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// Drop every cached tensor (tests; stats are left monotone).
pub fn weight_cache_clear() {
    cache().lock().unwrap().clear();
}

/// Specialized b-posit⟨32,6,5⟩ encoder for f32 inputs (scalar fast path).
///
/// Mirrors the Pallas kernel's contract exactly: f32 subnormal inputs
/// (|x| < 2^−126) quantize to 0 (the f32 pipeline is FTZ/DAZ end-to-end),
/// NaN/Inf → NaR. For normal f32 the result is bit-identical to the
/// general pattern-space-RNE codec (proved by exhaustive-sampled parity
/// tests below). Kept as an *independent implementation* of the lane
/// encoder — the test oracle neither derives from nor feeds the generic
/// engine.
#[inline]
pub fn fast_bp32_encode(x: f32) -> u32 {
    let bits = x.to_bits();
    let sign = bits >> 31;
    let biased = (bits >> 23) & 0xff;
    let f23 = bits & 0x7f_ffff;
    if biased == 0 {
        return 0; // zero and FTZ'd subnormals
    }
    if biased == 0xff {
        return 0x8000_0000; // NaN/Inf → NaR
    }
    let t = biased as i32 - 127;
    let r = t >> 5;
    let e5 = (t - (r << 5)) as u32;
    // r ∈ [-4, 4] for every normal f32 (t ∈ [-126, 127]) — always in range.
    // Regime field + size (capped forms unreachable from f32 range).
    let (reg, k) = if r >= 0 {
        ((((1u32 << (r + 1)) - 1) << 1), (r + 2) as u32)
    } else {
        (1u32, (1 - r) as u32)
    };
    let fw = 26 - k; // fraction width, 21..=24
    let base = ((reg << 5) | e5) << fw;
    // Fraction: f23 realigned to fw bits with RNE (fw ≥ 21 ⇒ drop ≤ 2).
    let body = if fw >= 23 {
        base + (f23 << (fw - 23))
    } else {
        let d = 23 - fw;
        let q = f23 >> d;
        let rem = f23 & ((1 << d) - 1);
        let half = 1 << (d - 1);
        let up = (rem > half) || (rem == half && q & 1 == 1);
        base + q + up as u32 // carry propagates across field boundaries:
                             // posit patterns are monotone-contiguous.
    };
    if sign == 1 {
        body.wrapping_neg()
    } else {
        body
    }
}

/// Specialized b-posit⟨32,6,5⟩ decoder to f32 (scalar fast path;
/// select-based, mirrors the Pallas kernel; FTZ contract below 2^−126,
/// ±Inf above f32 range).
#[inline]
pub fn fast_bp32_decode(word: u32) -> f32 {
    if word == 0 {
        return 0.0;
    }
    if word == 0x8000_0000 {
        return f32::NAN;
    }
    let sign = word >> 31;
    let body = if sign == 1 { word.wrapping_neg() } else { word } & 0x7fff_ffff;
    let m = (body >> 30) & 1;
    // First opposite bit among the 5 probes (or capped run of 6).
    let xb = ((body >> 25) & 0x1f) ^ (0x1f * m);
    let run = if xb == 0 { 6 } else { xb.leading_zeros() - 27 + 1 }; // 1..=6
    let reg_len = if run == 6 { 6 } else { run + 1 };
    let r = if m == 1 { run as i32 - 1 } else { -(run as i32) };
    let payload = body << (reg_len + 1); // exp at bit 31
    let e = (payload >> 27) as i32;
    let f = (payload >> 3) & 0xff_ffff; // 24 fraction bits
    let t = r * 32 + e;
    if t < -126 {
        return if sign == 1 { -0.0 } else { 0.0 }; // FTZ contract
    }
    if t > 127 {
        return if sign == 1 { f32::NEG_INFINITY } else { f32::INFINITY };
    }
    // Assemble: 24-bit fraction RNE'd to 23 bits (guard = bit 0).
    let q = f >> 1;
    let up = (f & 1 == 1) && (q & 1 == 1); // tie → even (no sticky below)
    let frac = q + up as u32;
    let (t, frac) = if frac >> 23 != 0 { (t + 1, 0) } else { (t, frac) };
    if t > 127 {
        return if sign == 1 { f32::NEG_INFINITY } else { f32::INFINITY };
    }
    f32::from_bits((sign << 31) | (((t + 127) as u32) << 23) | frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_encode_parity_with_general_codec() {
        // Exhaustive-grade PRNG sweep + corners: the fast path must agree
        // bit-for-bit with the general codec on every f32 — including
        // subnormals, now that the general path applies the FTZ contract.
        let mut x = 0x853c49e6748fea9bu64;
        let mut checked = 0u32;
        for _ in 0..2_000_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = f32::from_bits(x as u32);
            if !v.is_finite() {
                continue;
            }
            assert_eq!(
                fast_bp32_encode(v),
                quantize_one_general(v) as u32,
                "fast/general encode mismatch for {v} ({:#010x})",
                v.to_bits()
            );
            checked += 1;
        }
        assert!(checked > 1_000_000);
        for v in [0.0f32, -0.0, 1.0, -1.0, f32::MAX, f32::MIN_POSITIVE, f32::NAN, f32::INFINITY] {
            let fast = fast_bp32_encode(v);
            if v == 0.0 {
                assert_eq!(fast, 0);
            } else {
                assert_eq!(fast, quantize_one_general(v) as u32, "corner {v}");
            }
        }
    }

    #[test]
    fn general_codec_ftz_contract() {
        // The satellite contract: subnormal f32 inputs quantize to 0 in the
        // general path too, so general/fast parity is exact everywhere.
        for bits in [1u32, 0x0000_0001, 0x007f_ffff, 0x807f_ffff, 0x8000_0001] {
            let v = f32::from_bits(bits);
            assert!(v == 0.0 || v.abs() < f32::MIN_POSITIVE);
            assert_eq!(quantize_one_general(v), 0, "FTZ for {bits:#010x}");
            assert_eq!(quantize_one_general(v), quantize_one(v), "parity for {bits:#010x}");
        }
        assert_eq!(quantize_one_general(f32::NAN) as u32, 0x8000_0000);
    }

    #[test]
    fn fast_decode_parity_with_general_codec() {
        // With the FTZ contract applied on both sides, decode parity is
        // direct equality (NaN excepted).
        let mut x = 0x2545f4914f6cdd1du64;
        for _ in 0..2_000_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let w = x as u32;
            let fast = fast_bp32_decode(w);
            let gen: f32 = dequantize_one_general(w as i32);
            if gen.is_nan() {
                assert!(fast.is_nan());
                continue;
            }
            assert_eq!(fast, gen, "fast/general decode mismatch for {w:#010x}");
        }
    }

    #[test]
    fn fovea_values_are_exact() {
        let xs: Vec<f32> = (0..4096).map(|i| (i as f32 - 2048.0) * 0.37).collect();
        let rt = roundtrip(&xs);
        assert_eq!(xs, rt, "fovea f32 values must survive bp32 exactly");
    }

    #[test]
    fn specials() {
        assert_eq!(quantize_one(0.0f32), 0);
        assert_eq!(quantize_one(f32::NAN) as u32, 0x8000_0000);
        assert_eq!(quantize_one(f32::INFINITY) as u32, 0x8000_0000);
        assert!(dequantize_one::<i32, f32>(i32::MIN).is_nan());
        assert_eq!(dequantize_one::<i32, f32>(0), 0.0);
    }

    #[test]
    fn quantize_matches_python_kernel_contract() {
        // 1.0 → 0x40000000 etc. — the same patterns the Pallas kernel emits.
        assert_eq!(quantize_one(1.0f32) as u32, 0x4000_0000);
        assert_eq!(quantize_one(-1.0f32) as u32, 0xC000_0000);
        assert_eq!(dequantize_one::<i32, f32>(0x4000_0000u32 as i32), 1.0);
    }

    #[test]
    fn roundtrip_vec_len() {
        let v = vec![1.5f32; 100];
        assert_eq!(quantize(&v).len(), 100);
        assert_eq!(dequantize(&quantize(&v)), v);
    }

    #[test]
    fn batch_apis_match_scalar_fast_path() {
        // The vector-codec-backed slice APIs must agree element-for-element
        // with the scalar fast path (which itself matches the general codec).
        let mut rng = crate::testutil::Rng::new(0xfeed);
        let xs: Vec<f32> = (0..1000)
            .map(|_| {
                let v = f32::from_bits(rng.next_u32());
                if v.is_finite() { v } else { 1.0 }
            })
            .collect();
        let batch = quantize(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(batch[i], quantize_one(x), "quantize lane {i}");
            assert_eq!(batch[i] as u32, fast_bp32_encode(x), "fast-path parity lane {i}");
        }
        let back = dequantize(&batch);
        for (i, &b) in batch.iter().enumerate() {
            let one: f32 = dequantize_one(b);
            assert_eq!(back[i].to_bits(), one.to_bits(), "dequantize lane {i}");
        }
        let rt = roundtrip(&xs);
        let mut rt_ip = xs.clone();
        roundtrip_in_place(&mut rt_ip);
        for i in 0..xs.len() {
            assert_eq!(rt[i].to_bits(), rt_ip[i].to_bits());
            let one: f32 = dequantize_one(quantize_one(xs[i]));
            assert_eq!(rt[i].to_bits(), one.to_bits());
        }
    }

    #[test]
    fn bp64_batch_apis_match_general_codec() {
        let mut rng = crate::testutil::Rng::new(0xfee64);
        let xs: Vec<f64> = (0..2000)
            .map(|_| {
                let v = f64::from_bits(rng.next_u64());
                if v.is_finite() { v } else { 1.0 }
            })
            .collect();
        let batch = quantize64(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(batch[i], quantize64_one(x), "lane {i}");
            assert_eq!(batch[i], quantize64_one_general(x), "general parity {i}");
        }
        let back = dequantize64(&batch);
        for (i, &b) in batch.iter().enumerate() {
            assert_eq!(back[i].to_bits(), dequantize64_one(b).to_bits(), "lane {i}");
            assert_eq!(
                back[i].to_bits(),
                dequantize64_one_general(b).to_bits(),
                "general parity {i}"
            );
        }
        let rt = roundtrip64(&xs);
        let mut rt_ip = xs.clone();
        roundtrip64_in_place(&mut rt_ip);
        for i in 0..xs.len() {
            assert_eq!(rt[i].to_bits(), rt_ip[i].to_bits());
            assert_eq!(rt[i].to_bits(), dequantize64_one(quantize64_one(xs[i])).to_bits());
        }
    }

    #[test]
    fn generic_tiers_equal_named_64_aliases() {
        // The named 64-bit family and the generic family are the same
        // monomorphizations — spot-check every tier pair.
        let mut rng = crate::testutil::Rng::new(0x6e6e);
        for _ in 0..10_000 {
            let x = f64::from_bits(rng.next_u64());
            assert_eq!(quantize_one(x), quantize64_one(x));
            assert_eq!(quantize_one_general(x), quantize64_one_general(x));
            let b = rng.next_u64() as i64;
            let g: f64 = dequantize_one(b);
            assert!(
                g.to_bits() == dequantize64_one(b).to_bits()
                    || (g.is_nan() && dequantize64_one(b).is_nan())
            );
        }
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 0.125 - 6.0).collect();
        assert_eq!(quantize(&xs), quantize64(&xs));
        assert_eq!(roundtrip(&xs), roundtrip64(&xs));
    }

    #[test]
    fn bp64_quantize_is_lossless_in_range() {
        // ⟨64,6,5⟩ carries ≥ 52 fraction bits everywhere: quantize64 of
        // any f64 in the 2^±192 range roundtrips exactly.
        let xs = [1.5e100f64, -std::f64::consts::PI, 2.0f64.powi(-190), 1.0 + f64::EPSILON];
        let rt = roundtrip64(&xs);
        for (a, b) in xs.iter().zip(&rt) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // FTZ + NaR specials.
        assert_eq!(quantize64_one(0.0), 0);
        assert_eq!(quantize64_one(f64::from_bits(1)), 0);
        assert_eq!(quantize64_one(f64::NAN) as u64, 1u64 << 63);
        assert!(dequantize64_one(i64::MIN).is_nan());
    }

    #[test]
    fn bp64_into_variants_reuse_buffers() {
        let xs = vec![2.5f64; 40];
        let mut bits = Vec::new();
        quantize64_into(&xs, &mut bits);
        let cap = bits.capacity();
        let mut back = Vec::new();
        dequantize64_into(&bits, &mut back);
        assert_eq!(back, xs);
        quantize64_into(&xs, &mut bits);
        assert_eq!(bits.capacity(), cap);
        assert_eq!(bits.len(), 40);
    }

    #[test]
    fn weight_cache_builds_once_per_content() {
        // Unique tag keeps this test independent of every other cache
        // user in the concurrently-running test process.
        let w: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 16.0).collect();
        let key = tensor_key_f32("test-cache-builds-once", 8, 8, &w);
        let builds = std::sync::atomic::AtomicU64::new(0);
        let build = || {
            builds.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            w.iter().map(|&x| quantize_one(x) as u32).collect::<Vec<u32>>()
        };
        let a = cached_weights_u32(key, build);
        let b = cached_weights_u32(key, build); // ref-capturing closure: Copy
        assert_eq!(builds.load(std::sync::atomic::Ordering::Relaxed), 1, "second lookup rebuilt");
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached allocation");
        // Different content (or dims, or tag) ⇒ different key.
        let mut w2 = w.clone();
        w2[0] += 1.0;
        assert_ne!(key, tensor_key_f32("test-cache-builds-once", 8, 8, &w2));
        assert_ne!(key, tensor_key_f32("test-cache-builds-once", 4, 16, &w));
        assert_ne!(key, tensor_key_f32("test-cache-builds-once2", 8, 8, &w));
        let bits: Vec<i32> = w.iter().map(|&x| quantize_one(x)).collect();
        let k1 = tensor_key_i32("test-cache-i32", 8, 8, &bits);
        let mut bits2 = bits.clone();
        bits2[5] ^= 1;
        assert_ne!(k1, tensor_key_i32("test-cache-i32", 8, 8, &bits2));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let xs = vec![2.5f32; 40];
        let mut bits = Vec::new();
        quantize_into(&xs, &mut bits);
        let cap = bits.capacity();
        let mut back: Vec<f32> = Vec::new();
        dequantize_into(&bits, &mut back);
        assert_eq!(back, xs);
        // Re-running with the same size must not reallocate.
        quantize_into(&xs, &mut bits);
        assert_eq!(bits.capacity(), cap);
        assert_eq!(bits.len(), 40);
    }
}
