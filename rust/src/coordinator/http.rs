//! Zero-dependency HTTP/1.1 listener over [`std::net::TcpListener`] —
//! the serving stack's real network surface (the vendored dependency set
//! has no hyper/axum):
//!
//! - `GET /metrics` — the Prometheus-style text from
//!   [`super::MetricsSnapshot::render`].
//! - `GET /healthz` — liveness probe (`ok`).
//! - `POST /infer` — body `{"features":[…]}`; replies
//!   `{"logits":[…],"latency_us":N,"trace_id":N}` (the trace id
//!   correlates with the request's span in `/debug/tracez`). Infer
//!   errors map to status codes: bad request → 400, queue full
//!   (backpressure) → 503, deadline → 504, backend failure → 500.
//! - `GET /debug/tracez` — the span ring as JSON, filterable by
//!   `?min_us=` (drop spans faster than this) and `?limit=` (newest-N);
//!   unknown `/debug/*` paths 404 like any other route.
//!
//! One accept thread, one short-lived thread per connection
//! (connections are `Connection: close`; the real concurrency limit is
//! the server's bounded queue, which turns overload into 503s rather
//! than unbounded threads). Request heads are capped at 16 KiB and
//! bodies at 4 MiB; reads time out so a stalled peer can't pin a thread.
//! Connections and responses (by status class) are counted into
//! [`super::Metrics`]; successful `/infer` requests complete their trace
//! span *here* — after the response bytes are written — so the span's
//! serialize/write stages and total wall time cover the full HTTP
//! lifetime, not just the inference.
//!
//! Float fidelity: logits are rendered with Rust's shortest-roundtrip
//! float formatting and parsed back via f64, which is lossless for every
//! finite f32 — the HTTP round-trip is bit-exact (tests gate on this).

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::json::Json;

use super::server::{InferError, InferenceServer};
use super::trace::{SpanRecord, Stage, StageTimer, TRACE_RING_CAP};

const MAX_HEAD_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Cap on live connection threads: past this, new connections get an
/// immediate 503 instead of a thread — a stalled-peer (slowloris) flood
/// can pin at most this many threads for `READ_TIMEOUT`.
const MAX_CONN_THREADS: usize = 64;

/// A running HTTP listener bound to an [`InferenceServer`]. Shuts down
/// (and joins the accept thread) on drop.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

/// Bind `addr` (e.g. `"127.0.0.1:8080"`, or port `0` for an ephemeral
/// port) and serve `server` until the returned handle is dropped.
pub fn serve(addr: &str, server: Arc<InferenceServer>) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr().context("local_addr")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let active = Arc::new(AtomicUsize::new(0));
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => {
                    // e.g. EMFILE under fd pressure: back off instead of
                    // busy-spinning the accept loop.
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };
            server.metrics().record_http_conn_open();
            if active.load(Ordering::SeqCst) >= MAX_CONN_THREADS {
                let body = error_body("too many connections");
                let _ = write_response(
                    &mut stream,
                    503,
                    "Service Unavailable",
                    "application/json",
                    &body,
                );
                server.metrics().record_http_response(503);
                server.metrics().record_http_conn_close();
                continue;
            }
            active.fetch_add(1, Ordering::SeqCst);
            let srv = server.clone();
            let act = active.clone();
            std::thread::spawn(move || {
                handle_conn(stream, &srv);
                srv.metrics().record_http_conn_close();
                act.fetch_sub(1, Ordering::SeqCst);
            });
        }
    });
    Ok(HttpServer { addr: local, stop, accept: Some(accept) })
}

impl HttpServer {
    /// The bound address (the actual port when bound with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// threads finish their single request and exit on their own.
    pub fn shutdown(&mut self) {
        let Some(handle) = self.accept.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let ip = match self.addr.ip() {
            ip if ip.is_unspecified() && ip.is_ipv4() => IpAddr::V4(Ipv4Addr::LOCALHOST),
            ip if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
            ip => ip,
        };
        let wake = SocketAddr::new(ip, self.addr.port());
        let woke = TcpStream::connect_timeout(&wake, Duration::from_millis(500)).is_ok();
        if woke {
            let _ = handle.join();
        }
        // If the self-connect failed (filtered interface, fd pressure),
        // the accept thread stays parked until the next stray connection;
        // leaking it beats blocking the caller in join() forever.
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct HttpRequest {
    method: String,
    path: String,
    /// Raw query string after `?` (empty when absent).
    query: String,
    body: Vec<u8>,
}

/// One routed response plus, for successful `/infer` requests, the trace
/// span to complete and retain after the bytes hit the socket.
struct Reply {
    status: u16,
    reason: &'static str,
    ctype: &'static str,
    body: String,
    span: Option<SpanRecord>,
}

impl Reply {
    fn new(status: u16, reason: &'static str, ctype: &'static str, body: String) -> Reply {
        Reply { status, reason, ctype, body, span: None }
    }
}

fn handle_conn(mut stream: TcpStream, srv: &InferenceServer) {
    let t_conn = Instant::now();
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reply = match read_request(&mut stream) {
        Ok(req) => route(&req, srv, t_conn.elapsed()),
        Err(e) => Reply::new(400, "Bad Request", "application/json", error_body(&e)),
    };
    let t_write = Instant::now();
    let _ = write_response(&mut stream, reply.status, reply.reason, reply.ctype, &reply.body);
    srv.metrics().record_http_response(reply.status);
    if let Some(mut span) = reply.span.take() {
        // Complete the span only after the response is on the wire: the
        // write stage and the total cover the full connection lifetime.
        span.stages.add_duration(Stage::Write, t_write.elapsed());
        span.total_ns = t_conn.elapsed().as_nanos() as u64;
        srv.tracer().push(span);
    }
}

/// `accept` is the time spent reading the request off the socket —
/// charged to the trace span's `Accept` stage for `/infer`.
fn route(req: &HttpRequest, srv: &InferenceServer, accept: Duration) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => Reply::new(
            200,
            "OK",
            "text/plain; version=0.0.4",
            srv.metrics().snapshot().render(),
        ),
        ("GET", "/healthz") => Reply::new(200, "OK", "text/plain", "ok\n".to_string()),
        ("GET", "/debug/tracez") => tracez_route(req, srv),
        ("POST", "/infer") => infer_route(req, srv, accept),
        // Unknown paths — including unknown /debug/* — fall through here.
        _ => Reply::new(404, "Not Found", "application/json", error_body("no such route")),
    }
}

/// Extract one `name=value` pair from a raw query string.
fn query_param(query: &str, name: &str) -> Option<String> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == name).then(|| v.to_string())
    })
}

fn tracez_route(req: &HttpRequest, srv: &InferenceServer) -> Reply {
    let min_us: u64 =
        query_param(&req.query, "min_us").and_then(|v| v.parse().ok()).unwrap_or(0);
    let limit: usize =
        query_param(&req.query, "limit").and_then(|v| v.parse().ok()).unwrap_or(TRACE_RING_CAP);
    Reply::new(200, "OK", "application/json", srv.tracer().render_json(min_us, limit))
}

fn infer_route(req: &HttpRequest, srv: &InferenceServer, accept: Duration) -> Reply {
    let bad = |msg: &str| Reply::new(400, "Bad Request", "application/json", error_body(msg));
    let t_parse = Instant::now();
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return bad("body is not UTF-8");
    };
    let features = match Json::parse(text) {
        Ok(j) => match j.get("features").and_then(|f| f.as_f32_vec()) {
            Some(f) => f,
            None => return bad("body must be {\"features\": [..]}"),
        },
        Err(e) => return bad(&format!("bad JSON: {e}")),
    };
    let mut pre = StageTimer::default();
    pre.add_duration(Stage::Accept, accept);
    pre.add_duration(Stage::Parse, t_parse.elapsed());
    match srv.try_infer_traced(features, pre) {
        Ok(resp) => {
            let t_ser = Instant::now();
            let mut out = String::with_capacity(16 * resp.logits.len() + 48);
            out.push_str("{\"logits\":[");
            for (i, v) in resp.logits.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{v:?}"));
            }
            out.push_str(&format!(
                "],\"latency_us\":{},\"trace_id\":{}}}",
                resp.latency.as_micros(),
                resp.trace_id
            ));
            let mut reply = Reply::new(200, "OK", "application/json", out);
            if srv.tracer().enabled() {
                let mut stages = resp.stages;
                stages.add_duration(Stage::Serialize, t_ser.elapsed());
                // total_ns is re-stamped with the connection wall time
                // when the span completes in handle_conn.
                reply.span = Some(SpanRecord::request(
                    resp.trace_id,
                    resp.batch_id,
                    resp.batch_rows,
                    resp.latency.as_nanos() as u64,
                    stages,
                ));
            }
            reply
        }
        Err(InferError::BadRequest(m)) => bad(&m),
        Err(InferError::Busy) => Reply::new(
            503,
            "Service Unavailable",
            "application/json",
            error_body("server busy (queue full)"),
        ),
        Err(InferError::DeadlineExceeded) => Reply::new(
            504,
            "Gateway Timeout",
            "application/json",
            error_body("deadline exceeded before execution"),
        ),
        Err(InferError::Stopped) => Reply::new(
            500,
            "Internal Server Error",
            "application/json",
            error_body("server stopped"),
        ),
        Err(InferError::Backend(m)) => Reply::new(
            500,
            "Internal Server Error",
            "application/json",
            error_body(&format!("batch execution failed: {m}")),
        ),
    }
}

fn error_body(msg: &str) -> String {
    let escaped: String = msg
        .chars()
        .map(|ch| match ch {
            '"' => "\\\"".to_string(),
            '\\' => "\\\\".to_string(),
            '\n' => "\\n".to_string(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32),
            c => c.to_string(),
        })
        .collect();
    format!("{{\"error\":\"{escaped}\"}}")
}

fn read_request(stream: &mut TcpStream) -> std::result::Result<HttpRequest, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    // Read until the blank line that ends the header block.
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err("request head too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| "head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let raw_path = parts.next().ok_or("request line has no path")?;
    // Route on the path alone: `GET /metrics?format=x` must still hit
    // /metrics (Prometheus scrapers append query strings). The query is
    // kept separately for routes that do take parameters (tracez).
    let (path, query) = match raw_path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (raw_path.to_string(), String::new()),
    };
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| "bad Content-Length".to_string())?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err("body too large".into());
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| format!("read body: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, query, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    ctype: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Minimal blocking HTTP/1.1 client for tests and `serve-bench`: one
/// request per connection, returns `(status, body)`.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::result::Result<(u16, String), String> {
    let mut stream = TcpStream::connect_timeout(addr, Duration::from_secs(5))
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).map_err(|e| format!("read: {e}"))?;
    let text = String::from_utf8_lossy(&raw);
    let (head, resp_body) = text.split_once("\r\n\r\n").ok_or("response has no header end")?;
    let status_line = head.lines().next().ok_or("empty response")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or("status line has no code")?
        .parse()
        .map_err(|_| "bad status code".to_string())?;
    Ok((status, resp_body.to_string()))
}

/// Parse one `name value` line out of a Prometheus-style text body.
pub fn metric_value(metrics_text: &str, name: &str) -> Option<f64> {
    metrics_text.lines().find_map(|l| {
        let (n, v) = l.split_once(' ')?;
        if n == name {
            v.trim().parse().ok()
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_and_metric_parsing() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(16));
        assert_eq!(find_head_end(b"partial\r\n"), None);
        let text = "positron_batches_total 7\npositron_batch_mean_items 3.500\n";
        assert_eq!(metric_value(text, "positron_batches_total"), Some(7.0));
        assert_eq!(metric_value(text, "positron_batch_mean_items"), Some(3.5));
        assert_eq!(metric_value(text, "nope"), None);
    }

    #[test]
    fn query_param_parsing() {
        assert_eq!(query_param("min_us=250&limit=10", "min_us").as_deref(), Some("250"));
        assert_eq!(query_param("min_us=250&limit=10", "limit").as_deref(), Some("10"));
        assert_eq!(query_param("min_us=250", "limit"), None);
        assert_eq!(query_param("", "limit"), None);
        assert_eq!(query_param("flag&limit=3", "limit").as_deref(), Some("3"));
    }

    #[test]
    fn error_body_escapes_json() {
        assert_eq!(error_body("plain"), "{\"error\":\"plain\"}");
        assert_eq!(error_body("a\"b\\c\nd"), "{\"error\":\"a\\\"b\\\\c\\nd\"}");
        let parsed = Json::parse(&error_body("quote \" here")).unwrap();
        assert_eq!(parsed.get("error").unwrap().as_str(), Some("quote \" here"));
    }

    #[test]
    fn shortest_roundtrip_formatting_is_bit_exact_via_f64() {
        // The /infer response contract: Debug-format an f32, parse as
        // f64, cast back — must be the identical bit pattern.
        let mut rng = crate::testutil::Rng::new(0x4711);
        for _ in 0..100_000 {
            let v = f32::from_bits(rng.next_u32());
            if !v.is_finite() {
                continue;
            }
            let s = format!("{v:?}");
            let back = s.parse::<f64>().unwrap() as f32;
            assert_eq!(back.to_bits(), v.to_bits(), "{v} → {s} → {back}");
        }
    }
}
