//! Zero-dependency event-driven HTTP/1.1 front end over
//! [`std::net::TcpListener`] — the serving stack's real network surface
//! (the vendored dependency set has no hyper/axum/mio/tokio).
//!
//! # Routes
//!
//! - `GET /metrics` — Prometheus-style text from
//!   [`super::MetricsSnapshot::render`], aggregated across every
//!   registered model tier.
//! - `GET /healthz` — liveness probe (`ok`).
//! - `GET /v1/models` — the registered model tiers (name, weight
//!   format, feature/class dims, admission budget) and the default.
//! - `POST /v1/infer/<model>` — body `{"features":[…]}`; replies
//!   `{"logits":[…],"latency_us":N,"trace_id":N}` from the named tier.
//! - `POST /infer` — legacy alias for the default (first-registered)
//!   model; request/response bytes are identical to `/v1/infer/<model>`.
//! - `GET /debug/tracez` — the span ring as JSON, filterable by
//!   `?min_us=` and `?limit=`.
//!
//! Errors render a stable JSON body `{"code","message","trace_id"}`
//! (see [`ApiError`]; `trace_id` is 0 when the request never reached
//! the batch queue) with status 400/404/429/503/504/500, documented in
//! `docs/HTTP_API.md`.
//!
//! # Connection layer
//!
//! One event-loop thread owns every connection: a nonblocking accept
//! plus a readiness poller (`epoll` via raw syscall prototypes on
//! Linux — std already links libc, so declaring the three prototypes
//! ourselves keeps the dependency set empty — and `poll(2)` on other
//! unix) drives per-connection state machines with HTTP/1.1 keep-alive
//! and pipelining (responses are written strictly in request order).
//! Buffers are bounded (16 KiB heads, 4 MiB bodies, a write high-water
//! mark that pauses reads), and idle/read/write timeouts reap stalled
//! peers, so concurrency is limited by [`MAX_CONNS`] descriptors rather
//! than the old 64-thread cap. Inference never blocks the loop: requests
//! are submitted through [`InferenceServer::submit`] and the worker's
//! completion callback wakes the poller through a socketpair waker —
//! the same waker shutdown uses, so stopping needs no self-connect and
//! works with any number of open idle connections.
//!
//! Admission control fronts the batch queue: once
//! [`ModelRegistry::max_inflight`] requests sit between admission and
//! response write, further infer requests are shed with a fast 503 +
//! `Retry-After` after framing but *before* body parsing (counted in
//! `positron_http_shed_total`); a full batch queue is 429, a deadline
//! missed while queued is 504. Observability routes are never shed.
//! Connection states ([`Metrics::set_conn_states`]), keep-alive reuse,
//! and responses by status class are all exported via `/metrics`.
//!
//! [`serve_threaded`] keeps the PR 4 thread-per-connection design as a
//! one-request-per-connection baseline: `serve-bench` races the event
//! loop against it (CI gates on the event loop winning), and non-unix
//! builds fall back to it.
//!
//! Float fidelity: logits are rendered with Rust's shortest-roundtrip
//! float formatting and parsed back via f64, which is lossless for every
//! finite f32 — the HTTP round-trip is bit-exact (tests gate on this).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Context, Result};
use crate::json::Json;

use super::server::{Features, InferError, InferenceServer, ModelRegistry, Response};
use super::trace::{SpanRecord, Stage, StageTimer, TRACE_RING_CAP};

#[cfg(unix)]
use std::collections::{HashMap, VecDeque};
#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};
#[cfg(unix)]
use std::os::unix::net::UnixStream;
#[cfg(unix)]
use std::sync::mpsc::{Receiver, TryRecvError};

#[cfg(unix)]
use super::metrics::Metrics;
#[cfg(unix)]
use super::server::{Notify, ServeError, ServeResult};
#[cfg(unix)]
use super::trace::Tracer;

const MAX_HEAD_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// Mid-request stall budget: a connection that has started a request
/// but not completed it within this window is closed.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Keep-alive connections idle longer than this are closed.
const IDLE_TIMEOUT: Duration = Duration::from_secs(60);
/// A connection whose response bytes make no write progress for this
/// long (peer not reading) is closed.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Poller wait granularity — also the timeout-sweep cadence.
#[cfg(unix)]
const SWEEP_MS: i32 = 100;
/// Open-connection ceiling for the event loop; connections past this
/// get an immediate 503 and close. Replaces the old 64-thread cap.
pub const MAX_CONNS: usize = 4096;
/// Per-connection cap on pipelined requests awaiting responses; reads
/// pause (backpressure) once this many are outstanding.
#[cfg(unix)]
const PIPELINE_MAX: usize = 32;
/// Per-connection write-buffer high-water mark: reads pause until the
/// peer drains below this.
#[cfg(unix)]
const OUT_HIGH_WATER: usize = 256 * 1024;
/// Thread cap for the [`serve_threaded`] baseline (the PR 4 limit).
const MAX_CONN_THREADS: usize = 64;

#[cfg(unix)]
const TOKEN_LISTENER: u64 = u64::MAX;
#[cfg(unix)]
const TOKEN_WAKER: u64 = u64::MAX - 1;

// ---------------------------------------------------------------------------
// Readiness poller: epoll on Linux, poll(2) elsewhere on unix.
// ---------------------------------------------------------------------------

/// Raw syscall prototypes. std links the platform C library already, so
/// these `extern "C"` declarations add no dependency; constants are the
/// stable kernel ABI values.
#[cfg(target_os = "linux")]
mod sys {
    /// Mirror of the kernel's `struct epoll_event`. x86-64 is the one
    /// ABI where it is packed.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32)
            -> i32;
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    /// Mirror of `struct pollfd` (identical layout on every unix).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    extern "C" {
        /// `nfds_t` is `unsigned int` on the BSDs and macOS (the only
        /// non-Linux unix targets this fallback serves).
        pub fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }
}

/// One readiness event: `(token, readable, writable)`. Errors and
/// hangups surface as both, so the read/write paths observe them as
/// EOF/EPIPE and mark the connection dead.
#[cfg(unix)]
type ReadyEvent = (u64, bool, bool);

#[cfg(target_os = "linux")]
struct Poller {
    epfd: std::os::fd::OwnedFd,
    events: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl Poller {
    fn new() -> std::io::Result<Poller> {
        // SAFETY: plain FFI syscall with no pointer arguments; any return
        // value (including failure) is handled below.
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        // SAFETY: epoll_create1 returned a fresh descriptor we own; the
        // OwnedFd closes it on drop.
        let epfd = unsafe { std::os::fd::FromRawFd::from_raw_fd(fd) };
        Ok(Poller { epfd, events: vec![sys::EpollEvent { events: 0, data: 0 }; 1024] })
    }

    fn ctl(
        &mut self,
        op: i32,
        fd: RawFd,
        token: u64,
        read: bool,
        write: bool,
    ) -> std::io::Result<()> {
        let mut mask = 0u32;
        if read {
            mask |= sys::EPOLLIN;
        }
        if write {
            mask |= sys::EPOLLOUT;
        }
        let mut ev = sys::EpollEvent { events: mask, data: token };
        let evp =
            if op == sys::EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev as *mut _ };
        // SAFETY: `evp` is null only for EPOLL_CTL_DEL (where the kernel
        // ignores it) and otherwise points at `ev`, which outlives the call.
        let rc = unsafe { sys::epoll_ctl(self.epfd.as_raw_fd(), op, fd, evp) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, read, write)
    }

    fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, read, write)
    }

    fn deregister(&mut self, fd: RawFd) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false)
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<ReadyEvent>) {
        out.clear();
        // SAFETY: pointer and capacity come from the same live Vec; the
        // kernel writes at most `events.len()` entries.
        let n = unsafe {
            sys::epoll_wait(
                self.epfd.as_raw_fd(),
                self.events.as_mut_ptr(),
                self.events.len() as i32,
                timeout_ms,
            )
        };
        if n < 0 {
            // EINTR: treat as a timeout round.
            return;
        }
        for ev in self.events.iter().take(n as usize) {
            let ev = *ev; // copy out of the (possibly packed) slot
            let err = ev.events & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
            let readable = ev.events & sys::EPOLLIN != 0 || err;
            let writable = ev.events & sys::EPOLLOUT != 0 || err;
            out.push((ev.data, readable, writable));
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
struct Poller {
    fds: Vec<sys::PollFd>,
    tokens: Vec<u64>,
}

#[cfg(all(unix, not(target_os = "linux")))]
impl Poller {
    fn new() -> std::io::Result<Poller> {
        Ok(Poller { fds: Vec::new(), tokens: Vec::new() })
    }

    fn mask(read: bool, write: bool) -> i16 {
        let mut m = 0i16;
        if read {
            m |= sys::POLLIN;
        }
        if write {
            m |= sys::POLLOUT;
        }
        m
    }

    fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> std::io::Result<()> {
        self.fds.push(sys::PollFd { fd, events: Self::mask(read, write), revents: 0 });
        self.tokens.push(token);
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> std::io::Result<()> {
        for (p, t) in self.fds.iter_mut().zip(self.tokens.iter_mut()) {
            if p.fd == fd {
                p.events = Self::mask(read, write);
                *t = token;
                return Ok(());
            }
        }
        Err(std::io::Error::from(std::io::ErrorKind::NotFound))
    }

    fn deregister(&mut self, fd: RawFd) -> std::io::Result<()> {
        if let Some(i) = self.fds.iter().position(|p| p.fd == fd) {
            self.fds.swap_remove(i);
            self.tokens.swap_remove(i);
        }
        Ok(())
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<ReadyEvent>) {
        out.clear();
        // SAFETY: pointer and length describe the same live Vec; poll(2)
        // only mutates the `revents` field of those entries.
        let n = unsafe { sys::poll(self.fds.as_mut_ptr(), self.fds.len() as u32, timeout_ms) };
        if n <= 0 {
            return;
        }
        for (p, &token) in self.fds.iter().zip(&self.tokens) {
            if p.revents == 0 {
                continue;
            }
            let err = p.revents & (sys::POLLERR | sys::POLLHUP) != 0;
            let readable = p.revents & sys::POLLIN != 0 || err;
            let writable = p.revents & sys::POLLOUT != 0 || err;
            out.push((token, readable, writable));
        }
    }
}

/// Wakes the event loop from other threads (worker completion
/// callbacks, shutdown): one byte down a nonblocking socketpair whose
/// read end the poller watches. A full pipe means a wake is already
/// pending, so a failed write is still a successful wake.
#[cfg(unix)]
#[derive(Clone)]
struct LoopWaker {
    tx: Arc<UnixStream>,
}

#[cfg(unix)]
impl LoopWaker {
    fn wake(&self) {
        let _ = (&*self.tx).write_all(&[1u8]);
    }
}

// ---------------------------------------------------------------------------
// Typed API errors.
// ---------------------------------------------------------------------------

/// Typed HTTP API error: every non-2xx response renders a stable JSON
/// body `{"code","message","trace_id"}` (`trace_id` is 0 when the
/// request never reached the batch queue). The variant fixes the status
/// code and the machine-readable `code` string; the message is
/// human-readable detail.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ApiError {
    /// 400 — malformed request line, head, JSON, or feature vector.
    BadRequest(String),
    /// 404 — unknown route or unregistered model name.
    NotFound(String),
    /// 429 — the batch queue is full (per-tier backpressure); retry.
    TooManyRequests(String),
    /// 503 — the listener shed the request before parsing it
    /// (admission budget or connection limit); retry.
    Overloaded(String),
    /// 504 — the request's deadline expired while it was queued.
    DeadlineExceeded(String),
    /// 500 — batch execution failed or the server is stopping.
    Internal(String),
}

impl ApiError {
    /// HTTP status code.
    pub fn status(&self) -> u16 {
        match self {
            ApiError::BadRequest(_) => 400,
            ApiError::NotFound(_) => 404,
            ApiError::TooManyRequests(_) => 429,
            ApiError::Overloaded(_) => 503,
            ApiError::DeadlineExceeded(_) => 504,
            ApiError::Internal(_) => 500,
        }
    }

    /// Stable machine-readable error code (the JSON `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::BadRequest(_) => "bad_request",
            ApiError::NotFound(_) => "not_found",
            ApiError::TooManyRequests(_) => "too_many_requests",
            ApiError::Overloaded(_) => "overloaded",
            ApiError::DeadlineExceeded(_) => "deadline_exceeded",
            ApiError::Internal(_) => "internal",
        }
    }

    /// HTTP reason phrase.
    pub fn reason(&self) -> &'static str {
        match self.status() {
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Internal Server Error",
        }
    }

    /// Suggested retry delay (seconds) — set on the retryable
    /// overload statuses (429/503) and rendered as `Retry-After`.
    pub fn retry_after(&self) -> Option<u32> {
        match self {
            ApiError::TooManyRequests(_) | ApiError::Overloaded(_) => Some(1),
            _ => None,
        }
    }

    /// Human-readable detail (the JSON `message` field).
    pub fn message(&self) -> &str {
        match self {
            ApiError::BadRequest(m)
            | ApiError::NotFound(m)
            | ApiError::TooManyRequests(m)
            | ApiError::Overloaded(m)
            | ApiError::DeadlineExceeded(m)
            | ApiError::Internal(m) => m,
        }
    }

    /// The stable JSON error body.
    pub fn render(&self, trace_id: u64) -> String {
        format!(
            "{{\"code\":\"{}\",\"message\":\"{}\",\"trace_id\":{trace_id}}}",
            self.code(),
            json_escape(self.message())
        )
    }
}

fn json_escape(msg: &str) -> String {
    msg.chars()
        .map(|ch| match ch {
            '"' => "\\\"".to_string(),
            '\\' => "\\\\".to_string(),
            '\n' => "\\n".to_string(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32),
            c => c.to_string(),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Request parsing and response rendering (shared by the event loop, the
// threaded baseline, and the keep-alive client).
// ---------------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    /// Raw query string after `?` (empty when absent).
    query: String,
    /// Whether the client asked to keep the connection open (HTTP/1.1
    /// default yes unless `Connection: close`; HTTP/1.0 default no
    /// unless `Connection: keep-alive`).
    keep_alive: bool,
    body: Vec<u8>,
}

/// Try to frame one request off the front of `buf`. `Ok(None)` means
/// incomplete (read more); `Ok(Some((req, consumed)))` hands back the
/// parsed request and how many bytes it occupied; `Err` is a framing
/// error the connection cannot recover from.
fn try_parse_request(buf: &[u8]) -> std::result::Result<Option<(HttpRequest, usize)>, String> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Err("request head too large".into());
        }
        return Ok(None);
    };
    if head_end > MAX_HEAD_BYTES {
        return Err("request head too large".into());
    }
    // lint:allow(no-indexing): head_end is a windows(4) position, so ≤ len - 4
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| "head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let raw_path = parts.next().ok_or("request line has no path")?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    // Route on the path alone: `GET /metrics?format=x` must still hit
    // /metrics (Prometheus scrapers append query strings). The query is
    // kept separately for routes that do take parameters (tracez).
    let (path, query) = match raw_path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (raw_path.to_string(), String::new()),
    };
    let mut content_length = 0usize;
    let mut connection = String::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| "bad Content-Length".to_string())?;
            } else if name.eq_ignore_ascii_case("connection") {
                connection = value.trim().to_ascii_lowercase();
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err("body too large".into());
    }
    let total = head_end + 4 + content_length;
    if buf.len() < total {
        return Ok(None);
    }
    let keep_alive = match version {
        "HTTP/1.0" => connection == "keep-alive",
        _ => connection != "close",
    };
    // lint:allow(no-indexing): `buf.len() < total` returned Ok(None) above
    let body = buf[head_end + 4..total].to_vec();
    Ok(Some((HttpRequest { method, path, query, keep_alive, body }, total)))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// One routed response plus, for successful `/infer` requests, the trace
/// span to complete and retain after the bytes hit the socket.
struct Reply {
    status: u16,
    reason: &'static str,
    ctype: &'static str,
    body: String,
    retry_after: Option<u32>,
    span: Option<SpanRecord>,
}

impl Reply {
    fn new(status: u16, reason: &'static str, ctype: &'static str, body: String) -> Reply {
        Reply { status, reason, ctype, body, retry_after: None, span: None }
    }
}

fn api_reply_with_id(e: ApiError, trace_id: u64) -> Reply {
    Reply {
        status: e.status(),
        reason: e.reason(),
        ctype: "application/json",
        body: e.render(trace_id),
        retry_after: e.retry_after(),
        span: None,
    }
}

fn api_reply(e: ApiError) -> Reply {
    api_reply_with_id(e, 0)
}

/// Serialize `reply` (status line, headers, body) into `out`.
fn render_response_into(out: &mut Vec<u8>, reply: &Reply, keep_alive: bool) {
    let retry = match reply.retry_after {
        Some(s) => format!("Retry-After: {s}\r\n"),
        None => String::new(),
    };
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{retry}\
         Connection: {conn}\r\n\r\n",
        reply.status,
        reply.reason,
        reply.ctype,
        reply.body.len()
    );
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(reply.body.as_bytes());
}

// ---------------------------------------------------------------------------
// Routing.
// ---------------------------------------------------------------------------

/// Routing outcome: either a reply the loop can send immediately, or
/// the inference tier the request must be dispatched to.
enum Routed {
    Immediate(Reply),
    Infer(Arc<InferenceServer>),
}

fn route_immediate(req: &HttpRequest, reg: &ModelRegistry) -> Routed {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => Routed::Immediate(Reply::new(
            200,
            "OK",
            "text/plain; version=0.0.4",
            reg.metrics().snapshot().render(),
        )),
        ("GET", "/healthz") => {
            Routed::Immediate(Reply::new(200, "OK", "text/plain", "ok\n".to_string()))
        }
        ("GET", "/debug/tracez") => Routed::Immediate(tracez_route(req, reg)),
        ("GET", "/v1/models") => Routed::Immediate(models_route(reg)),
        ("POST", "/infer") => match reg.default_entry() {
            Some(e) => Routed::Infer(e.server().clone()),
            None => Routed::Immediate(api_reply(ApiError::NotFound(
                "no models registered".into(),
            ))),
        },
        ("POST", p) if p.starts_with("/v1/infer/") => {
            // lint:allow(no-indexing): guarded by starts_with on an ASCII prefix
            let name = &p["/v1/infer/".len()..];
            match reg.get(name) {
                Some(s) => Routed::Infer(s.clone()),
                None => Routed::Immediate(api_reply(ApiError::NotFound(format!(
                    "no such model {name:?} (GET /v1/models lists registered models)"
                )))),
            }
        }
        // Unknown paths — including unknown /debug/* — fall through here.
        _ => Routed::Immediate(api_reply(ApiError::NotFound("no such route".into()))),
    }
}

/// Extract one `name=value` pair from a raw query string.
fn query_param(query: &str, name: &str) -> Option<String> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == name).then(|| v.to_string())
    })
}

fn tracez_route(req: &HttpRequest, reg: &ModelRegistry) -> Reply {
    let min_us: u64 =
        query_param(&req.query, "min_us").and_then(|v| v.parse().ok()).unwrap_or(0);
    let limit: usize =
        query_param(&req.query, "limit").and_then(|v| v.parse().ok()).unwrap_or(TRACE_RING_CAP);
    Reply::new(200, "OK", "application/json", reg.tracer().render_json(min_us, limit))
}

fn models_route(reg: &ModelRegistry) -> Reply {
    let mut body = String::from("{\"default\":");
    match reg.default_entry() {
        Some(e) => {
            body.push('"');
            body.push_str(e.name());
            body.push('"');
        }
        None => body.push_str("null"),
    }
    body.push_str(",\"models\":[");
    for (i, e) in reg.entries().iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let s = e.server();
        let (d, c) = s.dims;
        body.push_str(&format!(
            "{{\"name\":\"{}\",\"format\":\"{}\",\"features\":{d},\"classes\":{c},\
             \"max_inflight\":{}}}",
            e.name(),
            s.weight_format().name(),
            s.max_inflight()
        ));
    }
    body.push_str("]}");
    Reply::new(200, "OK", "application/json", body)
}

/// Parse the `{"features": [..]}` body at the width the target tier
/// serves: 64-bit activation tiers read full-precision f64 (staged
/// losslessly), everything else reads f32 as before.
fn parse_features(body: &[u8], f64_wanted: bool) -> std::result::Result<Features, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let missing = || "body must be {\"features\": [..]}".to_string();
    match Json::parse(text) {
        Ok(j) => {
            let f = j.get("features").ok_or_else(missing)?;
            if f64_wanted {
                f.as_f64_vec().map(Features::F64).ok_or_else(missing)
            } else {
                f.as_f32_vec().map(Features::F32).ok_or_else(missing)
            }
        }
        Err(e) => Err(format!("bad JSON: {e}")),
    }
}

fn infer_api_error(e: InferError) -> ApiError {
    match e {
        InferError::BadRequest(m) => ApiError::BadRequest(m),
        InferError::Busy => ApiError::TooManyRequests("server busy (queue full)".into()),
        InferError::DeadlineExceeded => {
            ApiError::DeadlineExceeded("deadline exceeded before execution".into())
        }
        InferError::Stopped => ApiError::Internal("server stopped".into()),
        InferError::Backend(m) => ApiError::Internal(format!("batch execution failed: {m}")),
    }
}

#[cfg(unix)]
fn serve_api_error(e: ServeError) -> ApiError {
    match e {
        ServeError::DeadlineExceeded => {
            ApiError::DeadlineExceeded("deadline exceeded before execution".into())
        }
        ServeError::BackendFailed(m) => {
            ApiError::Internal(format!("batch execution failed: {m}"))
        }
    }
}

/// Render a successful inference as the wire JSON, stamping the
/// serialize stage and (when `tracing`) carrying the request span for
/// completion after the bytes are written.
fn render_infer_ok(resp: &Response, tracing: bool) -> Reply {
    let t_ser = Instant::now();
    let mut out = String::with_capacity(16 * resp.logits.len() + 48);
    out.push_str("{\"logits\":[");
    for (i, v) in resp.logits.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{v:?}"));
    }
    out.push_str(&format!(
        "],\"latency_us\":{},\"trace_id\":{}",
        resp.latency.as_micros(),
        resp.trace_id
    ));
    // Sampled (certified) requests echo the max logit error bound so
    // clients can see the guarantee without scraping /metrics. A
    // poisoned (non-finite) bound serializes as null: "we sampled this
    // request but could not certify it" is different from silence.
    if let Some(w) = resp.certified_error_bound {
        if w.is_finite() {
            out.push_str(&format!(",\"certified_error_bound\":{w:?}"));
        } else {
            out.push_str(",\"certified_error_bound\":null");
        }
    }
    out.push('}');
    let mut reply = Reply::new(200, "OK", "application/json", out);
    if tracing {
        let mut stages = resp.stages;
        stages.add_duration(Stage::Serialize, t_ser.elapsed());
        // total_ns is re-stamped with the request wall time when the
        // span completes after the response bytes are flushed.
        reply.span = Some(SpanRecord::request(
            resp.trace_id,
            resp.batch_id,
            resp.batch_rows,
            resp.latency.as_nanos() as u64,
            stages,
        ));
    }
    reply
}

// ---------------------------------------------------------------------------
// The server handle.
// ---------------------------------------------------------------------------

/// A running HTTP listener. Shuts down (waking the event loop through
/// its poller — no self-connect) and joins its thread on drop.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    #[cfg(unix)]
    waker: Option<LoopWaker>,
    thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// The bound address (the actual port when bound with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread. The event loop drops
    /// every open connection (idle keep-alive peers included) on its
    /// next iteration, so this returns promptly regardless of open
    /// connections; it is woken through the poller, not a self-connect.
    pub fn shutdown(&mut self) {
        let Some(handle) = self.thread.take() else { return };
        // ORDERING: SeqCst store so the flag is visible before the poller
        // wake; shutdown is rare, cost is irrelevant.
        self.stop.store(true, Ordering::SeqCst);
        #[cfg(unix)]
        if let Some(w) = &self.waker {
            w.wake();
        }
        let _ = handle.join();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:8080"`, or port `0` for an ephemeral
/// port) and serve a single model until the returned handle is dropped.
/// The model is registered under its weight-format name (and as the
/// default, so legacy `POST /infer` works unchanged).
pub fn serve(addr: &str, server: Arc<InferenceServer>) -> Result<HttpServer> {
    let name = server.weight_format().name();
    let reg = ModelRegistry::from_server(name, server)?;
    serve_registry(addr, Arc::new(reg))
}

/// Bind `addr` and serve every model in `reg` from one event-driven
/// listener (`/v1/infer/<model>`). On non-unix targets this falls back
/// to the thread-per-connection baseline.
#[cfg(unix)]
pub fn serve_registry(addr: &str, reg: Arc<ModelRegistry>) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr().context("local_addr")?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let (wtx, wrx) = UnixStream::pair().context("waker socketpair")?;
    wtx.set_nonblocking(true).context("waker tx nonblocking")?;
    wrx.set_nonblocking(true).context("waker rx nonblocking")?;
    let waker = LoopWaker { tx: Arc::new(wtx) };
    let mut poller = Poller::new().context("create poller")?;
    poller
        .register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)
        .context("register listener")?;
    poller.register(wrx.as_raw_fd(), TOKEN_WAKER, true, false).context("register waker")?;
    let stop = Arc::new(AtomicBool::new(false));
    let w2 = waker.clone();
    let notify: Notify = Arc::new(move || w2.wake());
    let el = EventLoop {
        poller,
        listener,
        waker_rx: wrx,
        metrics: reg.metrics(),
        tracer: reg.tracer(),
        budget: reg.max_inflight().max(1),
        reg,
        stop: stop.clone(),
        conns: HashMap::new(),
        inflight: HashMap::new(),
        next_inflight: 0,
        notify,
    };
    let thread = std::thread::Builder::new()
        .name("positron-http".into())
        .spawn(move || el.run())
        .context("spawn event loop")?;
    Ok(HttpServer { addr: local, stop, waker: Some(waker), thread: Some(thread) })
}

/// Non-unix fallback: the readiness poller is unix-only, so other
/// targets serve through the thread-per-connection baseline (same
/// routes, `Connection: close`).
#[cfg(not(unix))]
pub fn serve_registry(addr: &str, reg: Arc<ModelRegistry>) -> Result<HttpServer> {
    serve_threaded_registry(addr, reg)
}

/// The PR 4 thread-per-connection listener, kept as the measured
/// baseline for the event loop (`serve-bench` races the two and CI
/// gates on the event loop winning) and as the non-unix fallback.
/// One request per connection (`Connection: close`), at most
/// [`MAX_CONN_THREADS`] concurrent handler threads.
pub fn serve_threaded(addr: &str, server: Arc<InferenceServer>) -> Result<HttpServer> {
    let name = server.weight_format().name();
    let reg = ModelRegistry::from_server(name, server)?;
    serve_threaded_registry(addr, Arc::new(reg))
}

fn serve_threaded_registry(addr: &str, reg: Arc<ModelRegistry>) -> Result<HttpServer> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr().context("local_addr")?;
    // Nonblocking accept + stop poll: shutdown needs no self-connect.
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let active = Arc::new(AtomicUsize::new(0));
    let thread = std::thread::Builder::new()
        .name("positron-http-threaded".into())
        .spawn(move || loop {
            // ORDERING: SeqCst pairs with the shutdown store; checked once
            // per accept round, so strength costs nothing.
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((mut stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    reg.metrics().record_http_conn_open();
                    // ORDERING: SeqCst keeps the admission check totally
                    // ordered with the handlers' fetch_add/fetch_sub; the
                    // cap may still overshoot by in-flight races, which
                    // admission tolerates.
                    if active.load(Ordering::SeqCst) >= MAX_CONN_THREADS {
                        let reply =
                            api_reply(ApiError::Overloaded("too many connections".into()));
                        let mut out = Vec::new();
                        render_response_into(&mut out, &reply, false);
                        let _ = stream.write_all(&out);
                        reg.metrics().record_http_shed();
                        reg.metrics().record_http_response(503);
                        reg.metrics().record_http_conn_close();
                        continue;
                    }
                    // ORDERING: SeqCst, same total order as the check above.
                    active.fetch_add(1, Ordering::SeqCst);
                    let r2 = reg.clone();
                    let act = active.clone();
                    std::thread::spawn(move || {
                        handle_conn_blocking(stream, &r2);
                        r2.metrics().record_http_conn_close();
                        // ORDERING: SeqCst release of this thread's slot.
                        act.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                Err(_) => {
                    // WouldBlock (poll the stop flag) or transient
                    // accept errors (EMFILE): back off briefly.
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        })
        .context("spawn accept loop")?;
    Ok(HttpServer {
        addr: local,
        stop,
        #[cfg(unix)]
        waker: None,
        thread: Some(thread),
    })
}

fn handle_conn_blocking(mut stream: TcpStream, reg: &ModelRegistry) {
    let t_conn = Instant::now();
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reply = match read_request_blocking(&mut stream) {
        Ok(req) => match route_immediate(&req, reg) {
            Routed::Immediate(r) => r,
            Routed::Infer(srv) => infer_blocking(&req, &srv, reg, t_conn.elapsed()),
        },
        Err(e) => api_reply(ApiError::BadRequest(e)),
    };
    let t_write = Instant::now();
    let mut out = Vec::new();
    render_response_into(&mut out, &reply, false);
    let _ = stream.write_all(&out);
    let _ = stream.flush();
    reg.metrics().record_http_response(reply.status);
    if let Some(mut span) = reply.span.take() {
        // Complete the span only after the response is on the wire: the
        // write stage and the total cover the full connection lifetime.
        span.stages.add_duration(Stage::Write, t_write.elapsed());
        span.total_ns = t_conn.elapsed().as_nanos() as u64;
        reg.tracer().push(span);
    }
}

fn read_request_blocking(stream: &mut TcpStream) -> std::result::Result<HttpRequest, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    loop {
        if let Some((req, _consumed)) = try_parse_request(&buf)? {
            return Ok(req);
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request".into());
        }
        // lint:allow(no-indexing): read() returns n ≤ chunk.len()
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Blocking dispatch for the threaded baseline — parses, submits, and
/// waits inline on the connection's own thread.
fn infer_blocking(
    req: &HttpRequest,
    srv: &InferenceServer,
    reg: &ModelRegistry,
    accept: Duration,
) -> Reply {
    let t_parse = Instant::now();
    let features = match parse_features(&req.body, srv.weight_format().f64_activations()) {
        Ok(f) => f,
        Err(msg) => return api_reply(ApiError::BadRequest(msg)),
    };
    let mut pre = StageTimer::default();
    pre.add_duration(Stage::Accept, accept);
    pre.add_duration(Stage::Parse, t_parse.elapsed());
    match srv.try_infer_traced(features, pre) {
        Ok(resp) => render_infer_ok(&resp, reg.tracer().enabled()),
        Err(e) => api_reply(infer_api_error(e)),
    }
}

// ---------------------------------------------------------------------------
// The event loop.
// ---------------------------------------------------------------------------

/// What a response slot is waiting on. Each parsed request claims one
/// slot in its connection's FIFO; responses are flushed strictly in
/// slot order, which is what makes pipelining answer in request order.
#[cfg(unix)]
enum Slot {
    /// Response rendered, ready to append to the write buffer.
    Ready(Rendered),
    /// Submitted to a tier's batch queue; the inflight table maps `id`
    /// back to this slot when the worker answers.
    Waiting { id: u64, keep_alive: bool, req_start: Instant },
}

#[cfg(unix)]
struct Rendered {
    reply: Reply,
    keep_alive: bool,
    req_start: Instant,
}

/// A request span waiting for its response bytes to reach the socket:
/// completed (write stage + total wall time) once the connection's
/// flushed-byte counter passes `end`.
#[cfg(unix)]
struct PendingSpan {
    end: u64,
    span: SpanRecord,
    appended_at: Instant,
    req_start: Instant,
}

#[cfg(unix)]
struct Conn {
    stream: TcpStream,
    in_buf: Vec<u8>,
    out_buf: Vec<u8>,
    out_pos: usize,
    /// Total response bytes ever queued / flushed on this connection
    /// (monotonic; `appended - flushed` is the unwritten backlog).
    appended: u64,
    flushed: u64,
    pending: VecDeque<Slot>,
    spans: VecDeque<PendingSpan>,
    /// Responses completed on this connection (keep-alive reuse count,
    /// recorded into `positron_keepalive_requests` at close).
    served: u64,
    /// When the bytes of the request currently being read first
    /// arrived — drives the read timeout and the Accept trace stage.
    req_start: Option<Instant>,
    last_activity: Instant,
    close_after_flush: bool,
    peer_closed: bool,
    dead: bool,
    cur_read: bool,
    cur_write: bool,
}

#[cfg(unix)]
impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            in_buf: Vec::new(),
            out_buf: Vec::new(),
            out_pos: 0,
            appended: 0,
            flushed: 0,
            pending: VecDeque::new(),
            spans: VecDeque::new(),
            served: 0,
            req_start: None,
            last_activity: Instant::now(),
            close_after_flush: false,
            peer_closed: false,
            dead: false,
            cur_read: true,
            cur_write: false,
        }
    }

    fn backlog(&self) -> usize {
        self.out_buf.len() - self.out_pos
    }
}

/// One submitted inference the loop is waiting on.
#[cfg(unix)]
struct Inflight {
    rx: Receiver<ServeResult>,
    fd: RawFd,
    trace_id: u64,
}

#[cfg(unix)]
struct EventLoop {
    poller: Poller,
    listener: TcpListener,
    waker_rx: UnixStream,
    reg: Arc<ModelRegistry>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
    stop: Arc<AtomicBool>,
    conns: HashMap<RawFd, Conn>,
    inflight: HashMap<u64, Inflight>,
    next_inflight: u64,
    /// Admission budget: infer requests are shed with 503 once this
    /// many sit between admission and response write.
    budget: usize,
    /// Completion callback passed to every submit — wakes the poller.
    notify: Notify,
}

#[cfg(unix)]
impl EventLoop {
    fn run(mut self) {
        let mut events: Vec<ReadyEvent> = Vec::new();
        loop {
            self.poller.wait(SWEEP_MS, &mut events);
            // ORDERING: SeqCst pairs with shutdown()'s store; once per
            // poll round, so strength is free.
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            for &(token, readable, writable) in &events {
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.drain_waker(),
                    t => self.conn_ready(t as RawFd, readable, writable),
                }
            }
            self.drain_inflight();
            self.sweep();
            self.update_gauges();
        }
        // Shutdown: drop every connection (keep-alive peers included).
        let fds: Vec<RawFd> = self.conns.keys().copied().collect();
        for fd in fds {
            self.close_conn(fd);
        }
        self.metrics.set_conn_states([0, 0, 0, 0]);
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.metrics.record_http_conn_open();
                    if self.conns.len() >= MAX_CONNS {
                        self.metrics.record_http_shed();
                        overload_close(stream, &self.metrics);
                        continue;
                    }
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    let fd = stream.as_raw_fd();
                    if self.poller.register(fd, fd as u64, true, false).is_err() {
                        self.metrics.record_http_conn_close();
                        continue;
                    }
                    self.conns.insert(fd, Conn::new(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break, // EMFILE etc.: retry next round
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut b = [0u8; 256];
        loop {
            match self.waker_rx.read(&mut b) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: drained
            }
        }
    }

    fn conn_ready(&mut self, fd: RawFd, readable: bool, writable: bool) {
        if !self.conns.contains_key(&fd) {
            return;
        }
        if readable {
            self.read_conn(fd);
            self.process_input(fd);
        }
        if readable || writable {
            self.flush_conn(fd);
        }
        self.finish_conn(fd);
    }

    fn read_conn(&mut self, fd: RawFd) {
        let Some(conn) = self.conns.get_mut(&fd) else { return };
        if conn.dead || conn.peer_closed {
            return;
        }
        let mut chunk = [0u8; 16 * 1024];
        loop {
            // Backpressure: stop reading while the response backlog is
            // deep (peer not draining) or the pipeline is full.
            if conn.pending.len() >= PIPELINE_MAX || conn.backlog() >= OUT_HIGH_WATER {
                break;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    if conn.req_start.is_none() {
                        conn.req_start = Some(Instant::now());
                    }
                    // lint:allow(no-indexing): read() returns n ≤ chunk.len()
                    conn.in_buf.extend_from_slice(&chunk[..n]);
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
    }

    /// Frame and dispatch every complete request buffered on `fd`.
    fn process_input(&mut self, fd: RawFd) {
        loop {
            enum Parsed {
                Req(HttpRequest, Instant),
                Stop,
            }
            let parsed = {
                let Some(conn) = self.conns.get_mut(&fd) else { return };
                if conn.dead
                    || conn.close_after_flush
                    || conn.in_buf.is_empty()
                    || conn.pending.len() >= PIPELINE_MAX
                {
                    break;
                }
                match try_parse_request(&conn.in_buf) {
                    Ok(None) => break,
                    Err(msg) => {
                        // Framing is unrecoverable: answer 400, close.
                        let req_start = conn.req_start.take().unwrap_or_else(Instant::now);
                        conn.in_buf.clear();
                        conn.pending.push_back(Slot::Ready(Rendered {
                            reply: api_reply(ApiError::BadRequest(msg)),
                            keep_alive: false,
                            req_start,
                        }));
                        Parsed::Stop
                    }
                    Ok(Some((req, consumed))) => {
                        conn.in_buf.drain(..consumed);
                        let req_start = conn.req_start.take().unwrap_or_else(Instant::now);
                        if !conn.in_buf.is_empty() {
                            conn.req_start = Some(Instant::now());
                        }
                        Parsed::Req(req, req_start)
                    }
                }
            };
            match parsed {
                Parsed::Stop => break,
                Parsed::Req(req, req_start) => {
                    let keep_alive = req.keep_alive;
                    self.dispatch(fd, req, req_start);
                    if !keep_alive {
                        break; // nothing pipelined past an explicit close
                    }
                }
            }
        }
        self.pump(fd);
    }

    /// Route one framed request: immediate routes render now; infer
    /// routes pass admission control and are submitted asynchronously.
    fn dispatch(&mut self, fd: RawFd, req: HttpRequest, req_start: Instant) {
        let keep_alive = req.keep_alive;
        let slot = match route_immediate(&req, &self.reg) {
            Routed::Immediate(reply) => {
                Slot::Ready(Rendered { reply, keep_alive, req_start })
            }
            Routed::Infer(srv) => {
                if self.inflight.len() >= self.budget {
                    // Load shed: framed but never parsed — the 503 goes
                    // out before any JSON work.
                    self.metrics.record_http_shed();
                    Slot::Ready(Rendered {
                        reply: api_reply(ApiError::Overloaded(format!(
                            "admission budget exhausted ({} inflight)",
                            self.budget
                        ))),
                        keep_alive,
                        req_start,
                    })
                } else {
                    let accept = req_start.elapsed();
                    let t_parse = Instant::now();
                    match parse_features(&req.body, srv.weight_format().f64_activations()) {
                        Err(msg) => Slot::Ready(Rendered {
                            reply: api_reply(ApiError::BadRequest(msg)),
                            keep_alive,
                            req_start,
                        }),
                        Ok(features) => {
                            let mut pre = StageTimer::default();
                            pre.add_duration(Stage::Accept, accept);
                            pre.add_duration(Stage::Parse, t_parse.elapsed());
                            match srv.submit(features, pre, Some(self.notify.clone())) {
                                Ok(pending) => {
                                    let id = self.next_inflight;
                                    self.next_inflight += 1;
                                    self.inflight.insert(
                                        id,
                                        Inflight {
                                            rx: pending.rx,
                                            fd,
                                            trace_id: pending.trace_id,
                                        },
                                    );
                                    Slot::Waiting { id, keep_alive, req_start }
                                }
                                Err(e) => Slot::Ready(Rendered {
                                    reply: api_reply(infer_api_error(e)),
                                    keep_alive,
                                    req_start,
                                }),
                            }
                        }
                    }
                }
            }
        };
        if let Some(conn) = self.conns.get_mut(&fd) {
            conn.pending.push_back(slot);
        }
    }

    /// Collect every completed inference and convert its slot to a
    /// rendered response, then flush the touched connections.
    fn drain_inflight(&mut self) {
        let mut completed: Vec<(u64, Option<ServeResult>)> = Vec::new();
        for (&id, inf) in &self.inflight {
            match inf.rx.try_recv() {
                Ok(res) => completed.push((id, Some(res))),
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => completed.push((id, None)),
            }
        }
        if completed.is_empty() {
            return;
        }
        let tracing = self.tracer.enabled();
        let mut touched: Vec<RawFd> = Vec::new();
        for (id, res) in completed {
            let Some(inf) = self.inflight.remove(&id) else { continue };
            let Some(conn) = self.conns.get_mut(&inf.fd) else {
                continue; // connection died while the batch ran
            };
            let Some((pos, keep_alive, req_start)) =
                conn.pending.iter().enumerate().find_map(|(p, s)| match s {
                    Slot::Waiting { id: i, keep_alive, req_start } if *i == id => {
                        Some((p, *keep_alive, *req_start))
                    }
                    _ => None,
                })
            else {
                continue;
            };
            let reply = match res {
                Some(Ok(resp)) => render_infer_ok(&resp, tracing),
                Some(Err(e)) => api_reply_with_id(serve_api_error(e), inf.trace_id),
                None => {
                    api_reply_with_id(ApiError::Internal("server stopped".into()), inf.trace_id)
                }
            };
            if let Some(slot) = conn.pending.get_mut(pos) {
                *slot = Slot::Ready(Rendered { reply, keep_alive, req_start });
            }
            touched.push(inf.fd);
        }
        touched.sort_unstable();
        touched.dedup();
        for fd in touched {
            // process_input (not just pump): requests that were parked
            // in the read buffer behind a full pipeline get framed now
            // that slots freed up.
            self.process_input(fd);
            self.flush_conn(fd);
            self.finish_conn(fd);
        }
    }

    /// Move every ready head-of-line response into the write buffer —
    /// responses leave strictly in request order.
    fn pump(&mut self, fd: RawFd) {
        let Some(conn) = self.conns.get_mut(&fd) else { return };
        while matches!(conn.pending.front(), Some(Slot::Ready(_))) {
            let Some(Slot::Ready(r)) = conn.pending.pop_front() else { break };
            append_response(conn, r, &self.metrics);
        }
    }

    fn flush_conn(&mut self, fd: RawFd) {
        let Some(conn) = self.conns.get_mut(&fd) else { return };
        if conn.dead {
            return;
        }
        while conn.out_pos < conn.out_buf.len() {
            // lint:allow(no-indexing): loop condition proves out_pos < len
            match conn.stream.write(&conn.out_buf[conn.out_pos..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.out_pos += n;
                    conn.flushed += n as u64;
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if conn.out_pos == conn.out_buf.len() && conn.out_pos > 0 {
            conn.out_buf.clear();
            conn.out_pos = 0;
        }
        // Complete spans whose response bytes are fully on the wire.
        let now = Instant::now();
        while conn.spans.front().is_some_and(|s| s.end <= conn.flushed) {
            let Some(mut ps) = conn.spans.pop_front() else { break };
            ps.span.stages.add_duration(Stage::Write, now.duration_since(ps.appended_at));
            ps.span.total_ns = now.duration_since(ps.req_start).as_nanos() as u64;
            self.tracer.push(ps.span);
        }
    }

    /// Close-or-reregister epilogue after any connection activity.
    fn finish_conn(&mut self, fd: RawFd) {
        let dead = {
            let Some(conn) = self.conns.get_mut(&fd) else { return };
            let drained = conn.backlog() == 0 && conn.pending.is_empty();
            if drained && (conn.close_after_flush || conn.peer_closed) {
                conn.dead = true;
            }
            conn.dead
        };
        if dead {
            self.close_conn(fd);
            return;
        }
        let mut modify_failed = false;
        if let Some(conn) = self.conns.get_mut(&fd) {
            let want_w = conn.backlog() > 0;
            let want_r = !conn.peer_closed
                && !conn.close_after_flush
                && conn.pending.len() < PIPELINE_MAX
                && conn.backlog() < OUT_HIGH_WATER;
            if (want_r, want_w) != (conn.cur_read, conn.cur_write) {
                if self.poller.modify(fd, fd as u64, want_r, want_w).is_ok() {
                    conn.cur_read = want_r;
                    conn.cur_write = want_w;
                } else {
                    modify_failed = true;
                }
            }
        }
        if modify_failed {
            self.close_conn(fd);
        }
    }

    fn close_conn(&mut self, fd: RawFd) {
        let Some(conn) = self.conns.remove(&fd) else { return };
        let _ = self.poller.deregister(fd);
        self.metrics.record_http_conn_close();
        if conn.served > 0 {
            self.metrics.record_keepalive_requests(conn.served);
        }
        // `conn.stream` drops here, closing the descriptor (after the
        // poller no longer references it). Any inflight inferences it
        // was waiting on complete later and are discarded.
    }

    /// Reap stalled connections. A connection waiting on the batch
    /// worker is exempt — the server's deadline governs it, and the
    /// worker answers every admitted request.
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut doomed: Vec<RawFd> = Vec::new();
        for (&fd, conn) in &self.conns {
            let timeout = if !conn.pending.is_empty() {
                None
            } else if conn.backlog() > 0 {
                Some(WRITE_TIMEOUT)
            } else if conn.req_start.is_some() {
                Some(READ_TIMEOUT)
            } else {
                Some(IDLE_TIMEOUT)
            };
            if let Some(t) = timeout {
                if now.duration_since(conn.last_activity) > t {
                    doomed.push(fd);
                }
            }
        }
        for fd in doomed {
            self.close_conn(fd);
        }
    }

    /// Recompute the connection-state partition gauge
    /// (`positron_http_conn_state`): writing > inflight > reading >
    /// idle, one state per connection.
    fn update_gauges(&self) {
        let mut states = [0u64; 4];
        for conn in self.conns.values() {
            let i = if conn.backlog() > 0 {
                3
            } else if conn.pending.iter().any(|s| matches!(s, Slot::Waiting { .. })) {
                2
            } else if conn.req_start.is_some() || !conn.in_buf.is_empty() {
                1
            } else {
                0
            };
            // lint:allow(no-indexing): i is one of the literals 0..=3 above
            states[i] += 1;
        }
        self.metrics.set_conn_states(states);
    }
}

/// Best-effort 503 to a connection rejected at the [`MAX_CONNS`] cap.
#[cfg(unix)]
fn overload_close(mut stream: TcpStream, metrics: &Metrics) {
    let reply = api_reply(ApiError::Overloaded("connection limit reached".into()));
    let mut out = Vec::new();
    render_response_into(&mut out, &reply, false);
    let _ = stream.set_nonblocking(true);
    let _ = stream.write_all(&out);
    metrics.record_http_response(503);
    metrics.record_http_conn_close();
}

/// Serialize one response onto `conn`'s write buffer and account for it
/// (status-class counter, reuse count, span scheduling, close-after).
#[cfg(unix)]
fn append_response(conn: &mut Conn, r: Rendered, metrics: &Metrics) {
    let Rendered { reply, keep_alive, req_start } = r;
    let keep_alive = keep_alive && !conn.close_after_flush;
    let before = conn.out_buf.len();
    render_response_into(&mut conn.out_buf, &reply, keep_alive);
    conn.appended += (conn.out_buf.len() - before) as u64;
    conn.served += 1;
    metrics.record_http_response(reply.status);
    if let Some(span) = reply.span {
        conn.spans.push_back(PendingSpan {
            end: conn.appended,
            span,
            appended_at: Instant::now(),
            req_start,
        });
    }
    if !keep_alive {
        conn.close_after_flush = true;
    }
}

// ---------------------------------------------------------------------------
// Clients.
// ---------------------------------------------------------------------------

/// One parsed HTTP response from [`HttpClient`].
pub struct HttpResponse {
    /// Status code.
    pub status: u16,
    /// Response body.
    pub body: String,
    head: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup (trimmed value).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.head.lines().skip(1).find_map(|l| {
            let (n, v) = l.split_once(':')?;
            n.trim().eq_ignore_ascii_case(name).then(|| v.trim())
        })
    }
}

/// Minimal blocking keep-alive HTTP/1.1 client: many requests down one
/// connection, with `send`/`recv` split so tests and `serve-bench` can
/// pipeline. Dropping it closes the connection.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    /// Open one keep-alive connection to `addr`.
    pub fn connect(addr: &SocketAddr) -> std::result::Result<HttpClient, String> {
        let stream = TcpStream::connect_timeout(addr, Duration::from_secs(5))
            .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
        let _ = stream.set_nodelay(true);
        Ok(HttpClient { stream, buf: Vec::new() })
    }

    /// Write one request without waiting for the response (pipelining).
    pub fn send(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::result::Result<(), String> {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: positron\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))
    }

    /// Read the next in-order response off the connection.
    pub fn recv(&mut self) -> std::result::Result<HttpResponse, String> {
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(pos) = find_head_end(&self.buf) {
                break pos;
            }
            if self.buf.len() > MAX_HEAD_BYTES {
                return Err("response head too large".into());
            }
            let n = self.stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
            if n == 0 {
                return Err("connection closed mid-response".into());
            }
            // lint:allow(no-indexing): read() returns n ≤ chunk.len()
            self.buf.extend_from_slice(&chunk[..n]);
        };
        // lint:allow(no-indexing): head_end is a windows(4) position, ≤ len - 4
        let head = String::from_utf8_lossy(&self.buf[..head_end]).to_string();
        let status_line = head.lines().next().ok_or("empty response")?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .ok_or("status line has no code")?
            .parse()
            .map_err(|_| "bad status code".to_string())?;
        let mut content_length = 0usize;
        for line in head.lines().skip(1) {
            if let Some((name, value)) = line.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length =
                        value.trim().parse().map_err(|_| "bad Content-Length".to_string())?;
                }
            }
        }
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let n = self.stream.read(&mut chunk).map_err(|e| format!("read body: {e}"))?;
            if n == 0 {
                return Err("connection closed mid-body".into());
            }
            // lint:allow(no-indexing): read() returns n ≤ chunk.len()
            self.buf.extend_from_slice(&chunk[..n]);
        }
        // lint:allow(no-indexing): the while loop above read until len ≥ total
        let body = String::from_utf8_lossy(&self.buf[head_end + 4..total]).to_string();
        self.buf.drain(..total);
        Ok(HttpResponse { status, body, head })
    }

    /// One request-response round trip on the kept-alive connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::result::Result<HttpResponse, String> {
        self.send(method, path, body)?;
        self.recv()
    }
}

/// Minimal blocking one-shot HTTP client for tests and `serve-bench`:
/// one `Connection: close` request per connection, returns
/// `(status, body)`.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::result::Result<(u16, String), String> {
    let mut stream = TcpStream::connect_timeout(addr, Duration::from_secs(5))
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).map_err(|e| format!("read: {e}"))?;
    let text = String::from_utf8_lossy(&raw);
    let (head, resp_body) = text.split_once("\r\n\r\n").ok_or("response has no header end")?;
    let status_line = head.lines().next().ok_or("empty response")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or("status line has no code")?
        .parse()
        .map_err(|_| "bad status code".to_string())?;
    Ok((status, resp_body.to_string()))
}

/// Parse one `name value` line out of a Prometheus-style text body.
pub fn metric_value(metrics_text: &str, name: &str) -> Option<f64> {
    metrics_text.lines().find_map(|l| {
        let (n, v) = l.split_once(' ')?;
        if n == name {
            v.trim().parse().ok()
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_and_metric_parsing() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(16));
        assert_eq!(find_head_end(b"partial\r\n"), None);
        let text = "positron_batches_total 7\npositron_batch_mean_items 3.500\n";
        assert_eq!(metric_value(text, "positron_batches_total"), Some(7.0));
        assert_eq!(metric_value(text, "positron_batch_mean_items"), Some(3.5));
        assert_eq!(metric_value(text, "nope"), None);
    }

    #[test]
    fn query_param_parsing() {
        assert_eq!(query_param("min_us=250&limit=10", "min_us").as_deref(), Some("250"));
        assert_eq!(query_param("min_us=250&limit=10", "limit").as_deref(), Some("10"));
        assert_eq!(query_param("min_us=250", "limit"), None);
        assert_eq!(query_param("", "limit"), None);
        assert_eq!(query_param("flag&limit=3", "limit").as_deref(), Some("3"));
    }

    /// The typed error surface: status/code/retry mapping and the
    /// stable JSON body (escaping included) round-trip through the
    /// crate's own parser.
    #[test]
    fn api_error_mapping_and_body() {
        let cases: [(ApiError, u16, &str, Option<u32>); 6] = [
            (ApiError::BadRequest("x".into()), 400, "bad_request", None),
            (ApiError::NotFound("x".into()), 404, "not_found", None),
            (ApiError::TooManyRequests("x".into()), 429, "too_many_requests", Some(1)),
            (ApiError::Overloaded("x".into()), 503, "overloaded", Some(1)),
            (ApiError::DeadlineExceeded("x".into()), 504, "deadline_exceeded", None),
            (ApiError::Internal("x".into()), 500, "internal", None),
        ];
        for (e, status, code, retry) in cases {
            assert_eq!(e.status(), status);
            assert_eq!(e.code(), code);
            assert_eq!(e.retry_after(), retry);
            let parsed = Json::parse(&e.render(42)).unwrap();
            assert_eq!(parsed.get("code").unwrap().as_str(), Some(code));
            assert_eq!(parsed.get("trace_id").unwrap().as_f64(), Some(42.0));
        }
        let tricky = ApiError::BadRequest("a\"b\\c\nd".into());
        let parsed = Json::parse(&tricky.render(0)).unwrap();
        assert_eq!(parsed.get("message").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    /// Incremental framing: partial heads and bodies return `None`,
    /// complete requests report exact consumed lengths, and two
    /// pipelined requests frame one after the other.
    #[test]
    fn request_framing_and_pipelining() {
        assert!(matches!(try_parse_request(b"POST /infer HT"), Ok(None)));
        let one = b"POST /infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nab";
        assert!(matches!(try_parse_request(one), Ok(None)), "body incomplete");
        let mut buf = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec();
        buf.extend_from_slice(b"GET /b HTTP/1.1\r\n\r\n");
        let (req, used) = try_parse_request(&buf).unwrap().unwrap();
        assert_eq!((req.method.as_str(), req.path.as_str()), ("POST", "/a"));
        assert_eq!(req.body, b"hi");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        let rest = buf.split_off(used);
        let (req2, used2) = try_parse_request(&rest).unwrap().unwrap();
        assert_eq!((req2.method.as_str(), req2.path.as_str()), ("GET", "/b"));
        assert_eq!(used2, rest.len());
        assert!(try_parse_request(b"\r\n\r\n").is_err(), "empty request line");
    }

    /// Keep-alive negotiation across versions and Connection headers.
    #[test]
    fn keep_alive_negotiation() {
        let parse = |s: &[u8]| try_parse_request(s).unwrap().unwrap().0.keep_alive;
        assert!(parse(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(!parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n"));
        assert!(!parse(b"GET / HTTP/1.0\r\n\r\n"));
        assert!(parse(b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n"));
    }

    /// Responses carry the negotiated Connection header and Retry-After
    /// on the retryable overload statuses.
    #[test]
    fn response_rendering_headers() {
        let mut out = Vec::new();
        render_response_into(&mut out, &Reply::new(200, "OK", "text/plain", "ok".into()), true);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(!text.contains("Retry-After"), "{text}");
        let mut out = Vec::new();
        render_response_into(
            &mut out,
            &api_reply(ApiError::Overloaded("shed".into())),
            false,
        );
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    /// Tier-width feature parsing: f64 tiers keep full precision
    /// (values an f32 parse would collapse stay distinct), f32 tiers
    /// keep the historical narrowing, and both reject non-arrays.
    #[test]
    fn parse_features_honours_requested_width() {
        let body = br#"{"features": [0.1, 1.0000000000000002, -3.5]}"#;
        match parse_features(body, true).unwrap() {
            Features::F64(v) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[0].to_bits(), 0.1f64.to_bits());
                assert_eq!(v[1].to_bits(), 1.0000000000000002f64.to_bits());
            }
            Features::F32(_) => panic!("asked for f64, got f32"),
        }
        match parse_features(body, false).unwrap() {
            Features::F32(v) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[0].to_bits(), 0.1f32.to_bits());
                assert_eq!(v[1].to_bits(), 1.0f32.to_bits(), "narrowing collapses the ULP");
            }
            Features::F64(_) => panic!("asked for f32, got f64"),
        }
        for wanted in [false, true] {
            assert!(parse_features(br#"{"features": "nope"}"#, wanted).is_err());
            assert!(parse_features(b"not json", wanted).is_err());
        }
    }

    /// The optional `certified_error_bound` field: omitted for
    /// unsampled requests, a finite f64 for certified ones, and null
    /// when the sampled bound is poisoned (non-finite).
    #[test]
    fn infer_response_echoes_certified_bound() {
        let mut resp = Response {
            logits: vec![1.5, -2.0],
            latency: Duration::from_micros(7),
            trace_id: 42,
            batch_id: 1,
            batch_rows: 1,
            stages: StageTimer::default(),
            certified_error_bound: None,
        };
        let body = |r: &Response| render_infer_ok(r, false).body;
        assert!(!body(&resp).contains("certified_error_bound"));

        resp.certified_error_bound = Some(2.5e-6);
        let text = body(&resp);
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("certified_error_bound").unwrap().as_f64(), Some(2.5e-6));
        assert_eq!(j.get("trace_id").unwrap().as_f64(), Some(42.0));

        resp.certified_error_bound = Some(f64::INFINITY);
        let text = body(&resp);
        assert!(text.contains("\"certified_error_bound\":null"), "{text}");
        Json::parse(&text).unwrap();
    }

    #[test]
    fn shortest_roundtrip_formatting_is_bit_exact_via_f64() {
        // The /infer response contract: Debug-format an f32, parse as
        // f64, cast back — must be the identical bit pattern.
        let mut rng = crate::testutil::Rng::new(0x4711);
        for _ in 0..100_000 {
            let v = f32::from_bits(rng.next_u32());
            if !v.is_finite() {
                continue;
            }
            let s = format!("{v:?}");
            let back = s.parse::<f64>().unwrap() as f32;
            assert_eq!(back.to_bits(), v.to_bits(), "{v} → {s} → {back}");
        }
    }
}
