//! Test utilities: a deterministic PRNG (SplitMix64/xoshiro-class) and a
//! tiny property-testing runner (the vendored set has no proptest).

/// SplitMix64 — deterministic, seedable, good-enough mixing for tests.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// A "nasty" f64: mixes uniform bit patterns (hitting all scales),
    /// small integers, and special-ish values.
    pub fn nasty_f64(&mut self) -> f64 {
        match self.below(10) {
            0..=5 => f64::from_bits(self.next_u64()),
            6 => (self.below(2001) as f64 - 1000.0) / 8.0,
            7 => self.f64() * 2.0 - 1.0,
            8 => f64::powi(2.0, self.below(600) as i32 - 300) * (1.0 + self.f64()),
            _ => 0.0,
        }
    }
}

/// Mixed-scale finite f32s: |x| ∈ (0.5, 1.5)·2^±(scale_bits/2) with a
/// random sign — exercises every posit regime length without overflowing
/// f32 partial sums for moderate reductions. The one shared generator
/// behind the GEMM bench and the vector-layer test suites, so the
/// distribution can only be changed in one place.
pub fn mixed_scale_f32(rng: &mut Rng, len: usize, scale_bits: u64) -> Vec<f32> {
    (0..len)
        .map(|_| {
            let exp = rng.below(scale_bits) as i32 - (scale_bits as i32 / 2);
            let mag = (rng.f64() + 0.5) * f64::powi(2.0, exp);
            if rng.below(2) == 0 {
                mag as f32
            } else {
                -mag as f32
            }
        })
        .collect()
}

/// Mixed-scale finite f64s: the f64 analogue of [`mixed_scale_f32`],
/// shared by the 64-bit GEMM bench and the vector-layer test suites.
pub fn mixed_scale_f64(rng: &mut Rng, len: usize, scale_bits: u64) -> Vec<f64> {
    (0..len)
        .map(|_| {
            let exp = rng.below(scale_bits) as i32 - (scale_bits as i32 / 2);
            let mag = (rng.f64() + 0.5) * f64::powi(2.0, exp);
            if rng.below(2) == 0 { mag } else { -mag }
        })
        .collect()
}

/// Run a property `prop` over `n` PRNG-driven cases; panics with the seed
/// on failure so the case can be replayed.
pub fn forall(name: &str, n: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..n {
        let seed = 0xfeed_0000u64 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed {seed:#x}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_spreads() {
        let mut r = Rng::new(1);
        let mut buckets = [0u32; 16];
        for _ in 0..16000 {
            buckets[(r.next_u64() & 15) as usize] += 1;
        }
        for &b in &buckets {
            assert!(b > 700 && b < 1300, "bucket {b}");
        }
    }

    #[test]
    fn forall_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always-fails", 1, |_| Err("nope".into()));
        });
        assert!(result.is_err());
    }

    #[test]
    fn f64_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
