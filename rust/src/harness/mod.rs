//! Self-contained benchmark harness (criterion-style: warmup, calibrated
//! iteration counts, robust statistics). The vendored dependency set has no
//! criterion, so `cargo bench` targets use this.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Operations per second at the mean.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// One JSON object for `BENCH_*.json` artifacts (in-tree formatter;
    /// the offline dependency set has no serde).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.2},\"p50_ns\":{:.2},\"p99_ns\":{:.2},\"min_ns\":{:.2},\"ops_per_sec\":{:.2}}}",
            self.name,
            self.iters,
            self.mean_ns,
            self.p50_ns,
            self.p99_ns,
            self.min_ns,
            self.ops_per_sec()
        )
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    /// Target wall time per benchmark.
    pub budget: Duration,
    /// Warmup time before sampling.
    pub warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_millis(700),
            warmup: Duration::from_millis(150),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher::default()
    }

    /// Time `f` (which should perform ONE operation and return a value to
    /// keep the optimizer honest).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + per-iteration estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
        // Sample in batches sized so each sample is ≥ ~20 µs.
        let batch = ((20_000.0 / per_iter).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let res = BenchResult {
            name: name.to_string(),
            iters: batch * samples.len() as u64,
            mean_ns: mean,
            p50_ns: q(0.5),
            p99_ns: q(0.99),
            min_ns: samples[0],
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// JSON array of all results so far.
    pub fn results_json(&self) -> String {
        let items: Vec<String> = self.results.iter().map(|r| r.to_json()).collect();
        format!("[{}]", items.join(","))
    }

    /// Render an aligned results table.
    pub fn table(&self, title: &str) -> String {
        let mut s = format!("{title}\n");
        s.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}\n",
            "benchmark", "mean", "p50", "p99", "ops/s"
        ));
        for r in &self.results {
            s.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>14.0}\n",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p99_ns),
                r.ops_per_sec()
            ));
        }
        s
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            budget: Duration::from_millis(50),
            warmup: Duration::from_millis(10),
            results: vec![],
        };
        let r = b.bench("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7)).clone();
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns * 1.0001);
        assert!(r.min_ns <= r.mean_ns * 1.0001);
        assert!(r.iters > 100);
        let t = b.table("t");
        assert!(t.contains("noop-ish"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn json_output_parses() {
        let mut b = Bencher {
            budget: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            results: vec![],
        };
        b.bench("a/b/1", || 1u32);
        b.bench("c", || 2u32);
        let doc = crate::json::Json::parse(&b.results_json()).expect("valid JSON");
        match doc {
            crate::json::Json::Arr(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].get("name"), Some(&crate::json::Json::Str("a/b/1".into())));
                assert!(items[0].get("mean_ns").and_then(|v| v.as_f64()).unwrap() > 0.0);
            }
            other => panic!("expected array, got {other:?}"),
        }
    }
}
