//! Synthetic SPD operators for the solver benches and tests — bitwise
//! mirrored in `python/tests/test_solver_mirror.py` (same SplitMix64
//! draws, same summation order), so cross-language golden trajectories
//! can be pinned on them.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;

use crate::testutil::Rng;
use crate::vector::sparse::Csr;

/// 5-point 2D Poisson stencil on a `grid × grid` Dirichlet domain:
/// n = grid² unknowns, diagonal 4, neighbors −1. Symmetric positive
/// definite, and every value is a small integer — exactly representable
/// in every tier, which is what makes it the golden-trajectory operator.
pub fn poisson2d(grid: usize) -> Csr<f64> {
    assert!(grid >= 2, "poisson2d: grid must be at least 2");
    let n = grid * grid;
    let mut trips = Vec::with_capacity(5 * n);
    for i in 0..grid {
        for j in 0..grid {
            let k = i * grid + j;
            if i > 0 {
                trips.push((k, k - grid, -1.0));
            }
            if j > 0 {
                trips.push((k, k - 1, -1.0));
            }
            trips.push((k, k, 4.0));
            if j < grid - 1 {
                trips.push((k, k + 1, -1.0));
            }
            if i < grid - 1 {
                trips.push((k, k + grid, -1.0));
            }
        }
    }
    Csr::from_triplets(n, n, &trips).expect("poisson2d triplets are valid by construction")
}

/// Random symmetric operator: strictly diagonally dominant (Gershgorin
/// SPD, unit dominance margin) before an exact symmetric power-of-2
/// rescale `A′ = D·A·D`, `D = diag(2^eᵢ)` with `eᵢ` uniform in
/// `[-scale_pow, scale_pow]`. The congruence keeps A′ SPD while skewing
/// its diagonal over ~2^(2·scale_pow) — the conditioning the Jacobi
/// variant then removes (`scale_pow = 0` gives the plain
/// diagonally-dominant operator). `offdiag` is the number of off-diagonal
/// draws per row (duplicates and self-hits are dropped, so the realized
/// count per row is at most `2·offdiag`).
pub fn rand_dd(n: usize, offdiag: usize, scale_pow: u32, seed: u64) -> Csr<f64> {
    assert!(n >= 1, "rand_dd: empty operator");
    let mut rng = Rng::new(seed);
    let mut offd: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for i in 0..n {
        for _ in 0..offdiag {
            let j = rng.below(n as u64) as usize;
            if j == i {
                continue;
            }
            if let Entry::Vacant(e) = offd.entry((i.min(j), i.max(j))) {
                e.insert((rng.f64() - 0.5) * 2.0);
            }
        }
    }
    let span = 2 * scale_pow as u64 + 1;
    let exps: Vec<i32> = (0..n).map(|_| rng.below(span) as i32 - scale_pow as i32).collect();

    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for (&(i, j), &v) in &offd {
        rows[i].push((j, v));
        rows[j].push((i, v));
    }
    for row in rows.iter_mut() {
        row.sort_by_key(|&(c, _)| c);
    }
    // Diagonal: 1 + Σ|off-diagonal| in ascending column order — the same
    // fold order as the mirror, so the value is bit-identical.
    for i in 0..n {
        let mut diag = 1.0;
        for &(_, v) in &rows[i] {
            diag += v.abs();
        }
        rows[i].push((i, diag));
        rows[i].sort_by_key(|&(c, _)| c);
    }
    let mut trips = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        let si = f64::powi(2.0, exps[i]);
        for &(j, v) in row {
            trips.push((i, j, v * si * f64::powi(2.0, exps[j])));
        }
    }
    Csr::from_triplets(n, n, &trips).expect("rand_dd triplets are valid by construction")
}

/// The all-ones right-hand side used by the benches and goldens.
pub fn ones(n: usize) -> Vec<f64> {
    vec![1.0; n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_shape_and_symmetry() {
        let a = poisson2d(5);
        assert_eq!(a.rows(), 25);
        assert_eq!(a.nnz(), 5 * 25 - 4 * 5);
        let d = a.to_dense();
        for i in 0..25 {
            assert_eq!(d[i * 25 + i], 4.0);
            for j in 0..25 {
                assert_eq!(d[i * 25 + j], d[j * 25 + i]);
            }
        }
        assert_eq!(a.diag_f64(), vec![4.0; 25]);
    }

    #[test]
    fn rand_dd_symmetric_and_dominant_unscaled() {
        let a = rand_dd(48, 3, 0, 7);
        let d = a.to_dense();
        for i in 0..48 {
            let mut off = 0.0;
            for j in 0..48 {
                assert_eq!(d[i * 48 + j].to_bits(), d[j * 48 + i].to_bits());
                if j != i {
                    off += d[i * 48 + j].abs();
                }
            }
            // 0.5 margin absorbs the fold-order ulp (the constructor sums
            // with the +1.0 first).
            assert!(d[i * 48 + i] >= off + 0.5, "row {i}");
        }
    }

    #[test]
    fn rand_dd_scaling_is_exactly_symmetric() {
        let a = rand_dd(48, 3, 6, 7);
        let d = a.to_dense();
        for i in 0..48 {
            assert!(d[i * 48 + i] > 0.0);
            for j in 0..48 {
                assert_eq!(d[i * 48 + j].to_bits(), d[j * 48 + i].to_bits());
            }
        }
    }
}
