//! Tiered iterative solvers — the first workload layer to consume the
//! vector engine outside the HTTP serving path.
//!
//! A conjugate-gradient solver (plus a Jacobi-preconditioned variant)
//! over the sparse [`crate::vector::sparse`] layer, parameterized by an
//! **accumulation tier** ([`Tier`]):
//!
//! | tier      | operator storage        | reductions (dot + SpMV row)     |
//! |-----------|-------------------------|---------------------------------|
//! | `f32`     | f32 values              | fast 8-accumulator kernels      |
//! | `bp32`    | b-posit32 words         | fast, decode-fused              |
//! | `quire32` | f32 values              | 800-bit quire, one rounding     |
//! | `f64`     | f64 values              | fast 8-accumulator kernels      |
//! | `bp64`    | b-posit64 words         | fast, decode-fused              |
//! | `quire64` | f64 values              | 4416-bit quire, one rounding    |
//!
//! The quire tiers route every inner reduction through the exact
//! Kulisch accumulator (the [`crate::vector::kernels::QuireDot`] /
//! `QuireDotF64` semantics): each dot and each SpMV row is accumulated
//! exactly and rounded **once**. The bp tiers quantize the *operator*
//! (the serving-weight analogue) and decode-fuse the SpMV; iteration
//! vectors stay in the float exchange type. Scalars (α, β) always travel
//! as f64 and are rounded to the tier width before vector updates.
//!
//! Every iteration records the **exact** residual norm ‖r‖₂ (an
//! [`crate::formats::Quire::exact_f64`] self-dot, one rounding, then a
//! correctly-rounded sqrt) — the same tier-independent metric for every
//! trajectory entry and for the stopping test, so the tiers' convergence
//! curves are directly comparable. The whole recurrence is transliterated
//! from (and bitwise-validated against) the pure-stdlib Python mirror in
//! `python/tests/test_solver_mirror.py`; `tests/solver.rs` pins the
//! golden trajectories. See docs/SOLVERS.md for the full semantics and
//! the `BENCH_solver.json` trajectory schema.

pub mod operators;

use std::time::Instant;

use crate::formats::{Decoded, Quire};
use crate::vector::kernels;
use crate::vector::lane::LaneElem;
use crate::vector::sparse::{self, Csr, CsrWords};

/// Accumulation tier of a solve — see the module-level table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// f32 storage, fast reductions.
    F32,
    /// b-posit32-quantized operator, fast decode-fused reductions.
    Bp32,
    /// f32 storage, quire-exact reductions (800-bit paper quire).
    Quire32,
    /// f64 storage, fast reductions.
    F64,
    /// b-posit64-quantized operator, fast decode-fused reductions.
    Bp64,
    /// f64 storage, quire-exact reductions (4416-bit quire).
    Quire64,
}

impl Tier {
    /// All tiers, in bench emission order.
    pub const ALL: [Tier; 6] =
        [Tier::F32, Tier::Bp32, Tier::Quire32, Tier::F64, Tier::Bp64, Tier::Quire64];

    /// Stable name used in `BENCH_solver.json` and the CI gate.
    pub fn name(self) -> &'static str {
        match self {
            Tier::F32 => "f32",
            Tier::Bp32 => "bp32",
            Tier::Quire32 => "quire32",
            Tier::F64 => "f64",
            Tier::Bp64 => "bp64",
            Tier::Quire64 => "quire64",
        }
    }

    /// True for the quire-exact-reduction tiers.
    pub fn is_quire(self) -> bool {
        matches!(self, Tier::Quire32 | Tier::Quire64)
    }
}

/// Preconditioner choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precond {
    /// Plain CG.
    None,
    /// Jacobi (diagonal) preconditioning: z = D⁻¹r with the reciprocal
    /// diagonal precomputed in f64 and rounded once to the tier width
    /// (the apply is then multiply-only). Requires a nonzero diagonal.
    Jacobi,
}

impl Precond {
    /// Stable name used in `BENCH_solver.json`.
    pub fn name(self) -> &'static str {
        match self {
            Precond::None => "none",
            Precond::Jacobi => "jacobi",
        }
    }
}

/// Options for [`solve`].
#[derive(Clone, Copy, Debug)]
pub struct CgOptions {
    /// Relative tolerance: converged when ‖r‖₂ ≤ tol·‖b‖₂ (both norms
    /// exact).
    pub tol: f64,
    /// Iteration cap; a solve that reaches it reports `converged: false`.
    pub max_iters: usize,
    /// Preconditioner.
    pub precond: Precond,
}

impl Default for CgOptions {
    fn default() -> CgOptions {
        CgOptions { tol: 1e-6, max_iters: 500, precond: Precond::None }
    }
}

/// Result of one CG solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Completed CG iterations (SpMV applications) when the loop ended.
    pub iterations: usize,
    /// True when ‖r‖₂ ≤ tol·‖b‖₂ was reached within the cap.
    pub converged: bool,
    /// True when the pᵀAp curvature test failed (non-SPD operator or
    /// numerical collapse); the solve stops with the trajectory so far.
    pub breakdown: bool,
    /// Exact ‖r‖₂ per iteration, `iterations + 1` entries (entry 0 is the
    /// initial residual ‖b‖₂ since x₀ = 0).
    pub residuals: Vec<f64>,
    /// Last trajectory entry (the recurrence's own residual).
    pub final_residual: f64,
    /// Exact ‖b − Ax‖₂ recomputed from the final iterate against the
    /// operator as the tier sees it — exposes any drift between the
    /// recurrence residual and the true one.
    pub true_residual: f64,
    /// Wall time of the iteration loop (includes the per-iteration exact
    /// norm instrumentation, identically in every tier).
    pub wall_ns: u64,
    /// Final iterate, widened exactly to f64.
    pub x: Vec<f64>,
}

/// The operator as one tier sees it: storage flavor + reduction flavor.
enum TierOps<'a, E: LaneElem> {
    Fast(&'a Csr<E>),
    Quire(&'a Csr<E>),
    BpFast(&'a CsrWords<E>),
}

impl<E: LaneElem> TierOps<'_, E> {
    fn dims(&self) -> (usize, usize) {
        match self {
            TierOps::Fast(m) | TierOps::Quire(m) => (m.rows(), m.cols()),
            TierOps::BpFast(m) => (m.rows(), m.cols()),
        }
    }

    fn diag_f64(&self) -> Vec<f64> {
        match self {
            TierOps::Fast(m) | TierOps::Quire(m) => m.diag_f64(),
            TierOps::BpFast(m) => m.diag_f64(),
        }
    }

    fn quire_reductions(&self) -> bool {
        matches!(self, TierOps::Quire(_))
    }

    fn spmv(&self, x: &[E], y: &mut [E]) {
        match self {
            TierOps::Fast(m) => sparse::par_spmv(m, x, y),
            TierOps::Quire(m) => sparse::par_spmv_quire(m, x, y),
            TierOps::BpFast(m) => sparse::par_spmv_bp_weights_fast(m, x, y),
        }
    }

    /// Visit row `r` as (col, value-as-f64) — the values the kernels
    /// actually multiply by (decoded for the bp flavor).
    fn for_row(&self, r: usize, mut f: impl FnMut(usize, f64)) {
        match self {
            TierOps::Fast(m) | TierOps::Quire(m) => {
                let (idx, vals) = m.row(r);
                for (k, &c) in idx.iter().enumerate() {
                    f(c, vals[k].to_f64());
                }
            }
            TierOps::BpFast(m) => {
                let (idx, words) = m.row(r);
                for (k, &c) in idx.iter().enumerate() {
                    f(c, E::bp_decode_lane(words[k]).to_f64());
                }
            }
        }
    }
}

/// Solve `A·x = b` (A SPD, square) with CG at the given tier. The master
/// operator and right-hand side are f64; each tier first rounds them to
/// its own storage (one RNE rounding per value — exact for the f64 and,
/// for in-range values, bp64 tiers).
pub fn solve(a: &Csr<f64>, b: &[f64], tier: Tier, opts: &CgOptions) -> SolveReport {
    assert_eq!(a.rows(), a.cols(), "solve: operator must be square");
    assert_eq!(b.len(), a.rows(), "solve: rhs length mismatch");
    match tier {
        Tier::F32 => {
            let m = a.convert::<f32>();
            let bb: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            cg_impl(TierOps::Fast(&m), &bb, opts)
        }
        Tier::Bp32 => {
            let m = a.convert::<f32>().encode_bp();
            let bb: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            cg_impl(TierOps::BpFast(&m), &bb, opts)
        }
        Tier::Quire32 => {
            let m = a.convert::<f32>();
            let bb: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            cg_impl(TierOps::Quire(&m), &bb, opts)
        }
        Tier::F64 => cg_impl(TierOps::Fast(a), b, opts),
        Tier::Bp64 => {
            let m = a.encode_bp();
            cg_impl(TierOps::BpFast(&m), b, opts)
        }
        Tier::Quire64 => cg_impl(TierOps::Quire(a), b, opts),
    }
}

/// z ← M⁻¹r: the Jacobi apply (multiply by the precomputed reciprocal
/// diagonal) or the identity copy.
fn apply_precond<E: LaneElem>(inv_diag: &Option<Vec<E>>, r: &[E], z: &mut [E]) {
    match inv_diag {
        Some(d) => {
            for i in 0..r.len() {
                z[i] = r[i] * d[i];
            }
        }
        None => z.copy_from_slice(r),
    }
}

/// The CG recurrence — a line-for-line transliteration of the Python
/// mirror's `cg()` (see the module docs), shared by every tier.
fn cg_impl<E: LaneElem>(op: TierOps<'_, E>, b: &[E], opts: &CgOptions) -> SolveReport {
    let n = b.len();
    let (rows, cols) = op.dims();
    assert_eq!((rows, cols), (n, n), "cg: operator/rhs shape mismatch");
    let quire_red = op.quire_reductions();
    let inv_diag: Option<Vec<E>> = match opts.precond {
        Precond::Jacobi => Some(op.diag_f64().iter().map(|&d| E::from_f64(1.0 / d)).collect()),
        Precond::None => None,
    };
    // The tier quire serves the quire tiers' inner dots; the exact-f64
    // quire is the tier-independent norm instrument.
    let mut q_tier = E::quire();
    let mut q_norm = Quire::exact_f64();
    let dot_t = |q: &mut Quire, u: &[E], v: &[E]| -> f64 {
        if quire_red {
            kernels::quire_dot(q, u, v)
        } else {
            kernels::dot(u, v).to_f64()
        }
    };

    let mut x = vec![E::ZERO; n];
    let mut r: Vec<E> = b.to_vec();
    let mut z = vec![E::ZERO; n];
    apply_precond(&inv_diag, &r, &mut z);
    let mut p = z.clone();
    let mut ap = vec![E::ZERO; n];
    let mut rz = dot_t(&mut q_tier, &r, &z);
    let norm_b = kernels::quire_dot(&mut q_norm, b, b).sqrt();
    let threshold = opts.tol * norm_b;

    let mut residuals = Vec::new();
    let mut converged = false;
    let mut breakdown = false;
    let mut k = 0usize;
    let t0 = Instant::now(); // lint:allow(no-wallclock): wall-time budget check only; never feeds residuals or iterates
    loop {
        let res = kernels::quire_dot(&mut q_norm, &r, &r).sqrt();
        residuals.push(res);
        if res <= threshold {
            converged = true;
            break;
        }
        if k == opts.max_iters {
            break;
        }
        op.spmv(&p, &mut ap);
        let pap = dot_t(&mut q_tier, &p, &ap);
        if !pap.is_finite() || pap <= 0.0 {
            breakdown = true;
            break;
        }
        let alpha_e = E::from_f64(rz / pap);
        for i in 0..n {
            x[i] += alpha_e * p[i];
        }
        for i in 0..n {
            r[i] = r[i] - alpha_e * ap[i];
        }
        apply_precond(&inv_diag, &r, &mut z);
        let rz_new = dot_t(&mut q_tier, &r, &z);
        let beta_e = E::from_f64(rz_new / rz);
        for i in 0..n {
            p[i] = z[i] + beta_e * p[i];
        }
        rz = rz_new;
        k += 1;
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;

    // True residual: exact per-row b − Ax (one rounding per row), then
    // the exact norm of that vector.
    let mut tr = vec![0.0f64; n];
    for (i, tri) in tr.iter_mut().enumerate() {
        q_norm.clear();
        q_norm.add(&Decoded::from_f64(b[i].to_f64()));
        op.for_row(i, |c, a| {
            q_norm.sub_product(&Decoded::from_f64(a), &Decoded::from_f64(x[c].to_f64()));
        });
        *tri = q_norm.to_decoded().to_f64();
    }
    let true_residual = kernels::quire_dot(&mut q_norm, &tr, &tr).sqrt();

    SolveReport {
        iterations: k,
        converged,
        breakdown,
        final_residual: *residuals.last().unwrap(),
        residuals,
        true_residual,
        wall_ns,
        x: x.iter().map(|v| v.to_f64()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(g: usize) -> (Csr<f64>, Vec<f64>) {
        (operators::poisson2d(g), operators::ones(g * g))
    }

    #[test]
    fn identity_converges_in_one_iteration() {
        let trips: Vec<(usize, usize, f64)> = (0..9).map(|i| (i, i, 1.0)).collect();
        let a = Csr::from_triplets(9, 9, &trips).unwrap();
        let b = operators::ones(9);
        for tier in Tier::ALL {
            let rep = solve(&a, &b, tier, &CgOptions::default());
            assert!(rep.converged, "{}", tier.name());
            assert_eq!(rep.iterations, 1, "{}", tier.name());
            assert_eq!(rep.residuals.len(), 2, "{}", tier.name());
            assert_eq!(rep.x, b, "{}", tier.name());
            assert_eq!(rep.true_residual, 0.0, "{}", tier.name());
        }
    }

    #[test]
    fn every_tier_converges_on_small_poisson() {
        let (a, b) = poisson(8);
        for tier in Tier::ALL {
            let rep = solve(&a, &b, tier, &CgOptions::default());
            assert!(rep.converged, "{}", tier.name());
            assert!(!rep.breakdown, "{}", tier.name());
            assert_eq!(rep.residuals.len(), rep.iterations + 1, "{}", tier.name());
            assert_eq!(rep.final_residual, *rep.residuals.last().unwrap());
            // The recurrence residual and the true residual agree to the
            // tolerance scale.
            assert!(rep.true_residual <= 1e-5 * 8.0, "{}", tier.name());
        }
    }

    #[test]
    fn bp64_tier_is_bitwise_f64_on_integer_operator() {
        // BP64 encode never rounds in range (PR 3) and the Poisson values
        // are small integers, so the bp64 trajectory is bit-identical to
        // the f64 one.
        let (a, b) = poisson(8);
        let f = solve(&a, &b, Tier::F64, &CgOptions::default());
        let q = solve(&a, &b, Tier::Bp64, &CgOptions::default());
        assert_eq!(f.iterations, q.iterations);
        let fb: Vec<u64> = f.residuals.iter().map(|v| v.to_bits()).collect();
        let qb: Vec<u64> = q.residuals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, qb);
    }

    #[test]
    fn jacobi_is_bitwise_noop_on_constant_diagonal() {
        // Poisson's diagonal is the constant 4 = 2²: the Jacobi apply is
        // an exact power-of-two rescale, so the trajectory is unchanged
        // bit for bit (mirror-proven).
        let (a, b) = poisson(8);
        let plain = solve(&a, &b, Tier::F64, &CgOptions::default());
        let opts = CgOptions { precond: Precond::Jacobi, ..CgOptions::default() };
        let pre = solve(&a, &b, Tier::F64, &opts);
        assert_eq!(plain.iterations, pre.iterations);
        let pb: Vec<u64> = plain.residuals.iter().map(|v| v.to_bits()).collect();
        let qb: Vec<u64> = pre.residuals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(pb, qb);
    }

    #[test]
    fn non_spd_operator_reports_breakdown() {
        let trips = vec![(0, 0, -1.0f64), (1, 1, -1.0)];
        let a = Csr::from_triplets(2, 2, &trips).unwrap();
        let rep = solve(&a, &[1.0, 1.0], Tier::F64, &CgOptions::default());
        assert!(rep.breakdown);
        assert!(!rep.converged);
    }
}
