//! Regenerate the paper's hardware evaluation: Tables 5/6 (decoder/encoder
//! PPA at 16/32/64 bits), the Fig 14/15 comparisons, and the Fig 16
//! worst-case energy model — on the gate-level cost substrate.
//!
//! Run: `cargo run --release --example hw_cost_tables`

use positron::cli;
use positron::hw::report::{format_table, CostReport};

fn main() {
    let dec = cli::ppa_rows(false, 40);
    let enc = cli::ppa_rows(true, 40);
    println!("{}", format_table("Table 5 — decode PPA (45nm-class cell model)", &dec));
    println!("{}", format_table("Table 6 — encode PPA", &enc));

    // Fig 16: worst-case energy = (dec_delay + enc_delay) ×
    // (2·dec_power + enc_power)   [two decodes run in parallel]
    println!("Fig 16 — worst-case energy per two-operand op (pJ):");
    println!("{:<10} {:>10} {:>10} {:>10}", "width", "float", "b-posit", "posit");
    for (i, n) in [16, 32, 64].iter().enumerate() {
        let e = |d: &CostReport, en: &CostReport| {
            (d.delay_ns + en.delay_ns) * (2.0 * d.peak_power_mw + en.peak_power_mw)
        };
        let row = |k: usize| e(&dec[i * 3 + k], &enc[i * 3 + k]);
        println!("{:<10} {:>10.2} {:>10.2} {:>10.2}", n, row(0), row(1), row(2));
    }
    println!("\n(paper Fig 16: b-posits tie floats at 32 bits and use ~40% less energy at 64 bits)");
}
