//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the MLP trained at build time on the synthetic 16-class task
//! (`weights.json`), serves batched requests through the L3 coordinator
//! with concurrent clients on the **native** blocked-GEMM backend (f32
//! baseline vs b-posit32-quantized weights), and — when this build
//! carries the `runtime` feature — the PJRT backend over the compiled
//! HLO artifact for comparison. Reports accuracy plus
//! latency/throughput — the serving-paper-style validation required by
//! DESIGN.md.
//!
//! Run: `make artifacts && cargo run --release --example inference_server`

use std::sync::Arc;
use std::time::Instant;

use positron::coordinator::{BackendKind, InferenceServer, ServerConfig, WeightFormat};
use positron::runtime::{
    artifacts_available, default_artifact_dir, runtime_enabled, weights_available, ModelWeights,
};

fn main() -> positron::error::Result<()> {
    let dir = default_artifact_dir();
    if !weights_available(&dir) {
        eprintln!("weights.json missing in {} — run `make artifacts` first", dir.display());
        std::process::exit(1);
    }

    let weights = ModelWeights::load_from_dir(&dir)?;
    let d = weights.d;
    let n_gold = weights.golden_y.len();

    let mut variants = vec![
        ("native f32 baseline", BackendKind::Native, WeightFormat::F32),
        ("native b-posit quantized", BackendKind::Native, WeightFormat::Bp32),
        ("native b-posit64 tier", BackendKind::Native, WeightFormat::Bp64),
    ];
    if runtime_enabled() && artifacts_available(&dir) {
        variants.push(("pjrt b-posit quantized", BackendKind::Pjrt, WeightFormat::Bp32));
    }
    for (label, backend, format) in variants {
        let cfg = ServerConfig::builder().backend(backend).format(format).build()?;
        let server = Arc::new(InferenceServer::start(dir.clone(), cfg)?);

        // 4 concurrent clients × 512 requests each.
        let clients = 4;
        let per_client = 512;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for cid in 0..clients {
            let srv = server.clone();
            let w = weights.clone();
            handles.push(std::thread::spawn(move || {
                let mut correct = 0usize;
                let mut done = 0usize;
                for i in 0..per_client {
                    let g = (cid * 31 + i) % n_gold;
                    let feats = w.golden_x[g * d..(g + 1) * d].to_vec();
                    match srv.infer(feats) {
                        Ok(resp) => {
                            let argmax = resp
                                .logits
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                .unwrap()
                                .0;
                            if argmax == w.golden_y[g] as usize {
                                correct += 1;
                            }
                            done += 1;
                        }
                        Err(_) => {
                            // Backpressure: retry once after a beat.
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                    }
                }
                (correct, done)
            }));
        }
        let mut correct = 0usize;
        let mut done = 0usize;
        for h in handles {
            let (c, n) = h.join().unwrap();
            correct += c;
            done += n;
        }
        let wall = t0.elapsed();
        let m = server.metrics().snapshot();
        println!("== {label} ({} backend, {} weights) ==", backend.name(), format.name());
        println!(
            "  {done} requests in {:.2}s → {:.0} req/s, accuracy {:.1}%",
            wall.as_secs_f64(),
            done as f64 / wall.as_secs_f64(),
            100.0 * correct as f64 / done.max(1) as f64
        );
        println!(
            "  latency p50 {} µs, p99 {} µs | {} batches, mean batch {:.1}, rejected {}",
            m.p50_us, m.p99_us, m.batches, m.mean_batch, m.rejected
        );
    }
    println!("\nb-posit quantization preserves the classifier (paper: posit accuracy ≥ float at same width).");
    Ok(())
}
