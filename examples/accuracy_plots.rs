//! Regenerate the paper's accuracy figures as CSV + a terminal summary:
//! Fig 6a/6b (16-bit posit vs b-posit) and Fig 7 (float32 / posit32 /
//! takum32 / b-posit32), plus the Golden Zone / fovea / census claims.
//!
//! Run: `cargo run --release --example accuracy_plots [out_dir]`

use positron::accuracy::{self, decimals_at};
use positron::formats::posit::{BP16_E3, BP32, P16, P32};
use positron::formats::{ieee::F32, takum::T32, Codec};

fn main() {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "plots".into());
    std::fs::create_dir_all(&out_dir).expect("create output dir");

    // Fig 6: 16-bit accuracy curves.
    let fig6 = accuracy::curves_csv(&[("posit16", &P16), ("bposit16_e3", &BP16_E3)], -64, 64);
    std::fs::write(format!("{out_dir}/fig6_accuracy16.csv"), &fig6).unwrap();

    // Fig 7: 32-bit accuracy curves across the four formats.
    let fig7 = accuracy::curves_csv(
        &[("float32", &F32), ("posit32", &P32), ("takum32", &T32), ("bposit32", &BP32)],
        -260,
        260,
    );
    std::fs::write(format!("{out_dir}/fig7_accuracy32.csv"), &fig7).unwrap();
    println!("wrote {out_dir}/fig6_accuracy16.csv, {out_dir}/fig7_accuracy32.csv\n");

    // ASCII rendition of Fig 7 (decimals of accuracy vs scale).
    println!("Fig 7 (32-bit formats), decimals of accuracy:");
    println!("{:>6}  {:>8} {:>8} {:>8} {:>8}", "2^e", "float32", "posit32", "takum32", "bposit32");
    for e in (-256..=256).step_by(32) {
        let e = e as i32 - 0; // range covers both tails
        println!(
            "{:>6}  {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            e,
            decimals_at(&F32, e),
            decimals_at(&P32, e),
            decimals_at(&T32, e),
            decimals_at(&BP32, e)
        );
    }

    // The paper's headline claims, computed live.
    println!("\npaper claims:");
    let (lo, hi) = accuracy::golden_zone(&P32, &F32);
    println!("  posit32 Golden Zone:   2^{lo} … 2^{hi}   (paper: 2^-20 … 2^20)");
    let (blo, bhi) = accuracy::golden_zone(&BP32, &F32);
    println!("  b-posit32 Golden Zone: 2^{blo} … 2^{bhi} (paper: 2^-64 … 2^64)");
    let census = accuracy::pattern_census(&BP32, blo, bhi + 1);
    println!("  patterns inside:       {:.1}%        (paper: 75%)", census * 100.0);
    let (flo, fhi, fdec) = accuracy::fovea(&BP32);
    println!("  b-posit32 fovea:       2^{flo} … 2^{fhi} at {fdec:.2} decimals (paper: 2^-32 … 2^32)");
    let min16 = accuracy::curve(&BP16_E3, BP16_E3.min_scale(), BP16_E3.max_scale())
        .iter()
        .map(|p| p.decimals)
        .fold(f64::MAX, f64::min);
    println!("  ⟨16,6,3⟩ accuracy floor: {min16:.2} decimals  (paper: never below 2)");
}
