//! Quickstart: the format zoo in action — encode π across formats,
//! arithmetic with posit semantics, exact dot products with the quire.
//!
//! Run: `cargo run --release --example quickstart`

use positron::formats::posit::{BP32, P16, P32};
use positron::formats::{ieee, op_add, op_div, op_mul, op_sqrt, takum, Codec, Decoded, Quire};

fn main() {
    println!("=== positron quickstart ===\n");

    // Fig 1 of the paper: π at 16 bits — posit beats float.
    let pi = std::f64::consts::PI;
    println!("π = {pi}");
    for c in [
        &ieee::F16 as &dyn Codec,
        &P16,
        &ieee::F32,
        &P32,
        &BP32,
        &takum::T32,
    ] {
        let bits = c.encode(&Decoded::from_f64(pi));
        let back = c.decode(bits).to_f64();
        println!(
            "  {:<16} {:#0w$x}  → {:<20} rel err {:.3e}",
            c.name(),
            bits,
            back,
            ((back - pi) / pi).abs(),
            w = c.n() as usize / 4 + 2
        );
    }

    // The b-posit headline: huge dynamic range with guaranteed significance.
    println!("\nEinstein's cosmological constant Λ = 1.4657e-52 (paper §1.4):");
    let lam = 1.4657e-52;
    for c in [&ieee::F32 as &dyn Codec, &P32, &BP32] {
        let back = c.roundtrip_f64(lam);
        println!("  {:<16} → {back:e}", c.name());
    }

    // Arithmetic runs decode → exact compute → encode, like the hardware.
    println!("\nb-posit<32,6,5> arithmetic:");
    let a = BP32.from_f64(2.5);
    let b = BP32.from_f64(1.5);
    println!("  2.5 + 1.5 = {}", BP32.to_f64(op_add(&BP32, a, b)));
    println!("  2.5 × 1.5 = {}", BP32.to_f64(op_mul(&BP32, a, b)));
    println!("  2.5 ÷ 0   = NaR? {}", op_div(&BP32, a, 0) == BP32.nar());
    println!("  √2.5      = {}", BP32.to_f64(op_sqrt(&BP32, a)));

    // The quire: one rounding for a whole dot product (800 bits for ⟨n,6,5⟩).
    println!("\n800-bit quire ({} storage bits):", Quire::paper_800(&BP32).width());
    let mut q = Quire::exact_for(&BP32);
    let xs = [1e20, 3.0, -1e20, 4.0];
    let ys = [1.0, 1.0, 1.0, 0.25];
    for (x, y) in xs.iter().zip(&ys) {
        q.add_product(&Decoded::from_f64(*x), &Decoded::from_f64(*y));
    }
    println!("  Σ xᵢyᵢ with x = {xs:?}, y = {ys:?}");
    println!("  quire result  = {} (exact: 4.0)", q.to_decoded().to_f64());
    let mut naive = BP32.from_f64(0.0);
    for (x, y) in xs.iter().zip(&ys) {
        let prod = op_mul(&BP32, BP32.from_f64(*x), BP32.from_f64(*y));
        naive = op_add(&BP32, naive, prod);
    }
    println!("  naive result  = {} (cancellation lost the small terms)", BP32.to_f64(naive));

    // Comparisons are integer comparisons (posit superpower).
    println!("\ncomparison = signed integer compare:");
    let v = [-2.0f64, -0.5, 0.0, 0.5, 2.0];
    let mut bits: Vec<u64> = v.iter().map(|&x| BP32.from_f64(x)).collect();
    bits.sort_by(|&a, &b| BP32.cmp_bits(a, b));
    let sorted: Vec<f64> = bits.iter().map(|&b| BP32.to_f64(b)).collect();
    println!("  sorted via cmp_bits: {sorted:?}");
}
