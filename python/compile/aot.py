"""AOT pipeline: train the model, lower every computation to HLO **text**,
and emit the artifacts the Rust runtime loads.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
- model_f32.hlo.txt      f32 MLP forward: (x, w1, b1, w2, b2) → logits
- model_bposit.hlo.txt   quantized forward: weights as int32 b-posit words,
                         decoded in-graph by the Pallas kernels
- codec_decode.hlo.txt   batch b-posit32 → f32 (Pallas, select-based)
- codec_encode.hlo.txt   batch f32 → b-posit32
- weights.json           trained weights, quantized words, golden batch
- vectors.json           cross-language codec vectors (scalar oracle) for
                         rust/tests/golden_vectors.rs
- manifest.json          shapes + entry descriptions for the runtime

Python runs once, at build time; the Rust binary is self-contained after.
"""

import argparse
import json
import math
import os
import random
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import bposit, scalar

CODEC_LEN = 8192


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model_f32():
    spec = jax.ShapeDtypeStruct((model.BATCH, model.D), jnp.float32)
    w1 = jax.ShapeDtypeStruct((model.D, model.H), jnp.float32)
    b1 = jax.ShapeDtypeStruct((model.H,), jnp.float32)
    w2 = jax.ShapeDtypeStruct((model.H, model.C), jnp.float32)
    b2 = jax.ShapeDtypeStruct((model.C,), jnp.float32)

    def fn(x, w1, b1, w2, b2):
        return (model.forward_f32({"w1": w1, "b1": b1, "w2": w2, "b2": b2}, x),)

    return jax.jit(fn).lower(spec, w1, b1, w2, b2)


def lower_model_bposit():
    spec = jax.ShapeDtypeStruct((model.BATCH, model.D), jnp.float32)
    w1 = jax.ShapeDtypeStruct((model.D, model.H), jnp.int32)
    b1 = jax.ShapeDtypeStruct((model.H,), jnp.float32)
    w2 = jax.ShapeDtypeStruct((model.H, model.C), jnp.int32)
    b2 = jax.ShapeDtypeStruct((model.C,), jnp.float32)

    def fn(x, w1b, b1, w2b, b2):
        return (model.forward_bposit(x, w1b, b1, w2b, b2),)

    return jax.jit(fn).lower(spec, w1, b1, w2, b2)


def lower_codec():
    bits = jax.ShapeDtypeStruct((CODEC_LEN,), jnp.int32)
    xs = jax.ShapeDtypeStruct((CODEC_LEN,), jnp.float32)
    dec = jax.jit(lambda b: (bposit.decode(b),)).lower(bits)
    enc = jax.jit(lambda x: (bposit.encode(x),)).lower(xs)
    return dec, enc


def gen_vectors(path: str, cases_per_spec: int = 512) -> None:
    """Cross-language golden vectors from the scalar (big-int) oracle.

    Bit patterns and f64 values are emitted as hex strings so JSON never
    rounds anything.
    """
    random.seed(20260710)
    specs = [
        ("p16", scalar.P16),
        ("p32", scalar.P32),
        ("p64", scalar.P64),
        ("bp16", scalar.BP16),
        ("bp32", scalar.BP32),
        ("bp64", scalar.BP64),
        ("bp16e3", scalar.BP16_E3),
    ]
    out = []
    for name, sp in specs:
        dec_cases = []
        pats = [0, 1, sp.nar, sp.mask, sp.maxpos_body, sp.nar + 1, 1 << (sp.n - 2)]
        pats += [random.getrandbits(sp.n) for _ in range(cases_per_spec)]
        for p in pats:
            p &= sp.mask
            v = scalar.decode_f64(sp, p)
            dec_cases.append({"bits": f"{p:x}", "f64": f"{struct.unpack('<Q', struct.pack('<d', v))[0]:016x}"})
        enc_cases = []
        vals = [0.0, 1.0, -1.0, 1.5, math.pi, -math.e, 1e30, -1e-30, 6.6e-34, 1.4657e-52]
        vals += [random.uniform(-2.0, 2.0) * 10.0 ** random.randint(-60, 60) for _ in range(cases_per_spec)]
        for v in vals:
            bits = scalar.encode(sp, v)
            enc_cases.append({"f64": f"{struct.unpack('<Q', struct.pack('<d', v))[0]:016x}", "bits": f"{bits:x}"})
        out.append({"name": name, "n": sp.n, "rs": sp.rs, "es": sp.es, "decode": dec_cases, "encode": enc_cases})
    with open(path, "w") as f:
        json.dump(out, f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    def write(name: str, text: str) -> None:
        p = os.path.join(args.out_dir, name)
        with open(p, "w") as f:
            f.write(text)
        print(f"wrote {p} ({len(text)} chars)")

    print("training model (build-time only)…")
    params, history, acc = model.train(steps=args.steps)
    x, y = model.make_dataset(seed=1)
    qacc = model.quantized_accuracy(params, x, y)
    print(f"train acc f32={acc:.4f} bposit={qacc:.4f}")

    blob = model.export_weights(params, os.path.join(args.out_dir, "weights.json"), data_seed=1)
    print(f"wrote weights.json ({len(blob['w1'])}+{len(blob['w2'])} weights)")

    write("model_f32.hlo.txt", to_hlo_text(lower_model_f32()))
    write("model_bposit.hlo.txt", to_hlo_text(lower_model_bposit()))
    dec, enc = lower_codec()
    write("codec_decode.hlo.txt", to_hlo_text(dec))
    write("codec_encode.hlo.txt", to_hlo_text(enc))

    gen_vectors(os.path.join(args.out_dir, "vectors.json"))
    print("wrote vectors.json")

    manifest = {
        "model": {"batch": model.BATCH, "d": model.D, "h": model.H, "c": model.C},
        "codec_len": CODEC_LEN,
        "train": {"f32_acc": acc, "bposit_acc": qacc, "loss_history": history},
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
