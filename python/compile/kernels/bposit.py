"""Pallas kernels for the b-posit32 ⟨32,6,5⟩ codec and the quantized
matmul — Layer 1 of the stack.

These implement the paper's **select-based** decode/encode (Fig 12/13):
instead of a leading-zero count feeding a data-dependent barrel shift
(ref.py, the standard-posit architecture), every field is extracted by a
five-way select over *constant-shift* candidates keyed on a one-hot
regime-size detection. On an ASIC that's a 5-input mux; on the TPU VPU
it's branch-free vectorized selects with no per-lane variable shifts —
the same insight, mapped to SIMD (DESIGN.md §Hardware-Adaptation).

All kernels use interpret=True: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N = 32
RS = 6
ES = 5
FW = N - 3 - ES  # 24
NAR = -0x80000000  # NaR pattern as a plain int (jnp scalars cannot be captured by Pallas kernels)

N64 = 64
FW64 = N64 - 3 - ES  # 56 explicit fraction bits in the b-posit64 fovea
NAR64 = -0x8000000000000000


def _require_x64() -> None:
    """The 64-bit kernels need uint64/float64 lanes; fail with a clear
    message instead of silently truncating when jax x64 is off."""
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "b-posit64 kernels need 64-bit lanes: run with JAX_ENABLE_X64=1 "
            "or jax.config.update('jax_enable_x64', True)"
        )


# ----------------------------------------------------------------------
# Select-based scalar-vectorized codec (used inside the kernels)
# ----------------------------------------------------------------------

def decode_hw(bits):
    """Mux-based b-posit32 decode: int32 bits → float32 (paper Fig 12)."""
    u = bits.astype(jnp.uint32)
    sign = (u >> 31) & 1
    body = jnp.where(sign == 1, ~u + 1, u) & jnp.uint32(0x7FFFFFFF)
    m = ((body >> 30) & 1).astype(jnp.uint32)
    # The five probe bits after the regime MSB, XORed with it (Table 2).
    xb = ((body >> 25) & jnp.uint32(0x1F)) ^ (m * jnp.uint32(0x1F))
    x = [(xb >> (4 - i)) & 1 for i in range(5)]  # x[0] = first probe
    # One-hot regime-size conditions (prefix chain).
    s = []
    none_before = None
    for i in range(5):
        cond = x[i] == 1 if none_before is None else none_before & (x[i] == 1)
        s.append(cond)
        nb = x[i] == 0 if none_before is None else none_before & (x[i] == 0)
        none_before = nb
    s5 = none_before  # full six-bit run (Table 2 last row)

    # 5-way payload select over CONSTANT shifts (the one-hot mux):
    # regime size k ⇒ payload = body << (k+1), aligning exp at bit 31.
    def shifted(k):
        return (body << (k + 1)).astype(jnp.uint32)

    payload = jnp.where(
        s[0], shifted(2),
        jnp.where(s[1], shifted(3),
                  jnp.where(s[2], shifted(4),
                            jnp.where(s[3], shifted(5), shifted(6)))),
    )
    # Priority-encoded run length (1..6).
    run = jnp.where(
        s[0], 1, jnp.where(s[1], 2, jnp.where(s[2], 3, jnp.where(s[3], 4, jnp.where(s[4], 5, 6))))
    ).astype(jnp.int32)
    r = jnp.where(m == 1, run - 1, -run)
    e = (payload >> (32 - ES)).astype(jnp.int32)
    f = ((payload >> (32 - ES - FW)) & jnp.uint32((1 << FW) - 1)).astype(jnp.int32)
    t = r * (1 << ES) + e
    sig = 1.0 + f.astype(jnp.float32) / jnp.float32(1 << FW)
    val = jnp.ldexp(sig, jnp.maximum(t, -126)).astype(jnp.float32)
    val = jnp.where(t < -126, jnp.float32(0), val)  # flush contract
    val = jnp.where(sign == 1, -val, val)
    val = jnp.where(u == 0, jnp.float32(0), val)
    val = jnp.where(bits == jnp.int32(NAR), jnp.float32(jnp.nan), val)
    return val


def _rne_const(f, d):
    """RNE of f >> d for a *constant* d ≥ 1 (no variable shifts)."""
    q = f >> d
    rem = f & ((1 << d) - 1)
    half = 1 << (d - 1)
    up = (rem > half) | ((rem == half) & ((q & 1) == 1))
    return q + up.astype(q.dtype)


def encode_hw(x):
    """Mux-based b-posit32 encode: float32 → int32 bits (paper Fig 13).

    The regime field, fraction width, and rounding position are all chosen
    by selects over per-size constants — no data-dependent shifts.
    """
    xf = x.astype(jnp.float32)
    sign = xf < 0
    mag = jnp.abs(xf)
    mant, e2 = jnp.frexp(mag)
    t = e2.astype(jnp.int32) - 1
    f23 = jnp.round((mant * 2 - 1) * (1 << 23)).astype(jnp.uint32)
    r = t >> ES
    e5 = (t - (r << ES)).astype(jnp.uint32)

    # Candidate body for each regime size k: constant regime patterns and
    # constant shifts (Table 3/4 as selects).
    def body_for(k, reg_pattern):
        fw = (N - 1 - ES) - k  # 26 - k
        base = ((jnp.uint32(reg_pattern) << ES) | e5) << fw
        drop = 23 - fw
        frac = (f23 << (-drop)) if drop <= 0 else _rne_const(f23, drop)
        return base + frac

    # Regime pattern constants per r (r ∈ [-6, 5]) and size per r.
    # size(r): 0,-1→2; 1,-2→3; 2,-3→4; 3,-4→5; else→6.
    def reg_pat(rv):
        if rv >= 0:
            return (1 << RS) - 1 if rv >= RS - 1 else (((1 << (rv + 1)) - 1) << 1)
        return 0 if rv <= -RS else 1

    def size_of(rv):
        return min(max(rv + 2 if rv >= 0 else 1 - rv, 2), RS)

    body = jnp.zeros_like(f23)
    for rv in range(-RS, RS):
        cand = body_for(size_of(rv), reg_pat(rv))
        body = jnp.where(r == rv, cand, body)
    maxpos = jnp.uint32((1 << 31) - 1)
    body = jnp.where(r > RS - 1, maxpos, body)
    body = jnp.where(r < -RS, jnp.uint32(1), body)
    body = jnp.clip(body, jnp.uint32(1), maxpos)
    word = jnp.where(sign, ~body + 1, body).astype(jnp.int32)
    word = jnp.where(mag < jnp.float32(2.0**-126), jnp.int32(0), word)
    word = jnp.where(jnp.isnan(xf) | jnp.isinf(xf), jnp.int32(NAR), word)
    return word


# ----------------------------------------------------------------------
# 64-bit variants: b-posit64 ⟨64,6,5⟩ over int64/float64 lanes
# ----------------------------------------------------------------------
#
# Same five-way select structure (the regime bound rS=6 is width-
# independent — the paper's scalability claim), with two 64-bit-specific
# simplifications proven by the scalar oracle (compile/kernels/scalar.py):
# - encode never rounds: every regime size k ≤ 6 leaves fw = 58−k ≥ 52
#   fraction bits, so the 52-bit f64 mantissa always fits;
# - decode rounds once: the 56-bit fovea fraction is RNE'd to 52 bits as
#   an integer *before* the exact float conversion (a single rounding,
#   matching the Rust lane codec bit-for-bit).


def decode_hw64(bits):
    """Mux-based b-posit64 decode: int64 bits → float64."""
    _require_x64()
    u = bits.astype(jnp.uint64)
    sign = (u >> 63) & 1
    body = jnp.where(sign == 1, ~u + 1, u) & jnp.uint64(0x7FFFFFFFFFFFFFFF)
    m = ((body >> 62) & 1).astype(jnp.uint64)
    # The five probe bits after the regime MSB, XORed with it (Table 2).
    xb = ((body >> 57) & jnp.uint64(0x1F)) ^ (m * jnp.uint64(0x1F))
    x = [(xb >> (4 - i)) & 1 for i in range(5)]
    s = []
    none_before = None
    for i in range(5):
        cond = x[i] == 1 if none_before is None else none_before & (x[i] == 1)
        s.append(cond)
        nb = x[i] == 0 if none_before is None else none_before & (x[i] == 0)
        none_before = nb

    def shifted(k):
        return (body << (k + 1)).astype(jnp.uint64)

    payload = jnp.where(
        s[0], shifted(2),
        jnp.where(s[1], shifted(3),
                  jnp.where(s[2], shifted(4),
                            jnp.where(s[3], shifted(5), shifted(6)))),
    )
    run = jnp.where(
        s[0], 1, jnp.where(s[1], 2, jnp.where(s[2], 3, jnp.where(s[3], 4, jnp.where(s[4], 5, 6))))
    ).astype(jnp.int64)
    r = jnp.where(m == 1, run - 1, -run)
    e = (payload >> (64 - ES)).astype(jnp.int64)
    f = ((payload >> (64 - ES - FW64)) & jnp.uint64((1 << FW64) - 1)).astype(jnp.int64)
    t = r * (1 << ES) + e
    # Integer RNE 56 → 52 fraction bits; the carry bumps the scale.
    f52 = _rne_const(f, FW64 - 52)
    t = t + (f52 >> 52)
    f52 = f52 & jnp.int64((1 << 52) - 1)
    sig = 1.0 + f52.astype(jnp.float64) / jnp.float64(1 << 52)
    val = jnp.ldexp(sig, jnp.maximum(t, -1022)).astype(jnp.float64)
    val = jnp.where(t < -1022, jnp.float64(0), val)  # flush contract
    val = jnp.where(sign == 1, -val, val)
    val = jnp.where(u == 0, jnp.float64(0), val)
    val = jnp.where(bits == jnp.int64(NAR64), jnp.float64(jnp.nan), val)
    return val


def encode_hw64(x):
    """Mux-based b-posit64 encode: float64 → int64 bits.

    Unlike the 32-bit path, f64 exponents overrun the rS=6 regime bound
    (t ∈ [−1022, 1023] vs the ⟨64,6,5⟩ range 2^±192), so the saturation
    selects are live, and no fraction rounding ever happens (fw ≥ 52).
    """
    _require_x64()
    xf = x.astype(jnp.float64)
    sign = xf < 0
    mag = jnp.abs(xf)
    mant, e2 = jnp.frexp(mag)
    t = e2.astype(jnp.int64) - 1
    f52 = jnp.round((mant * 2 - 1) * (1 << 52)).astype(jnp.uint64)
    r = t >> ES
    e5 = (t - (r << ES)).astype(jnp.uint64)

    def body_for(k, reg_pattern):
        fw = (N64 - 1 - ES) - k  # 58 - k ≥ 52: fraction always fits
        base = ((jnp.uint64(reg_pattern) << ES) | e5) << fw
        return base + (f52 << (fw - 52))

    def reg_pat(rv):
        if rv >= 0:
            return (1 << RS) - 1 if rv >= RS - 1 else (((1 << (rv + 1)) - 1) << 1)
        return 0 if rv <= -RS else 1

    def size_of(rv):
        return min(max(rv + 2 if rv >= 0 else 1 - rv, 2), RS)

    body = jnp.zeros_like(f52)
    for rv in range(-RS, RS):
        cand = body_for(size_of(rv), reg_pat(rv))
        body = jnp.where(r == rv, cand, body)
    maxpos = jnp.uint64((1 << 63) - 1)
    body = jnp.where(r > RS - 1, maxpos, body)
    body = jnp.where(r < -RS, jnp.uint64(1), body)
    body = jnp.clip(body, jnp.uint64(1), maxpos)
    word = jnp.where(sign, ~body + 1, body).astype(jnp.int64)
    word = jnp.where(mag < jnp.float64(2.0**-1022), jnp.int64(0), word)
    word = jnp.where(jnp.isnan(xf) | jnp.isinf(xf), jnp.int64(NAR64), word)
    return word


# ----------------------------------------------------------------------
# Pallas kernels
# ----------------------------------------------------------------------

def _decode_kernel(bits_ref, o_ref):
    o_ref[...] = decode_hw(bits_ref[...])


def _encode_kernel(x_ref, o_ref):
    o_ref[...] = encode_hw(x_ref[...])


def _matmul_kernel(x_ref, wbits_ref, o_ref):
    # Decode the b-posit weight tile in VMEM, then feed the MXU-shaped dot.
    w = decode_hw(wbits_ref[...])
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def decode(bits, block=4096):
    """Decode a 1-D int32 array of b-posit32 words to float32 via Pallas."""
    (n,) = bits.shape
    if n % block != 0:
        block = n
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct(bits.shape, jnp.float32),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(bits)


@functools.partial(jax.jit, static_argnames=("block",))
def encode(x, block=4096):
    """Encode a 1-D float32 array into b-posit32 words via Pallas."""
    (n,) = x.shape
    if n % block != 0:
        block = n
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x)


def _decode64_kernel(bits_ref, o_ref):
    o_ref[...] = decode_hw64(bits_ref[...])


def _encode64_kernel(x_ref, o_ref):
    o_ref[...] = encode_hw64(x_ref[...])


def _matmul64_kernel(x_ref, wbits_ref, o_ref):
    w = decode_hw64(wbits_ref[...])
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float64)


@functools.partial(jax.jit, static_argnames=("block",))
def decode64(bits, block=4096):
    """Decode a 1-D int64 array of b-posit64 words to float64 via Pallas."""
    _require_x64()
    (n,) = bits.shape
    if n % block != 0:
        block = n
    return pl.pallas_call(
        _decode64_kernel,
        out_shape=jax.ShapeDtypeStruct(bits.shape, jnp.float64),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(bits)


@functools.partial(jax.jit, static_argnames=("block",))
def encode64(x, block=4096):
    """Encode a 1-D float64 array into b-posit64 words via Pallas."""
    _require_x64()
    (n,) = x.shape
    if n % block != 0:
        block = n
    return pl.pallas_call(
        _encode64_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int64),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul64(x, w_bits, bm=64, bn=128):
    """x (m,k) f64 @ decode64(w_bits) (k,n) → (m,n) f64, decode fused."""
    _require_x64()
    m, k = x.shape
    k2, n = w_bits.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _matmul64_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float64),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w_bits)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, w_bits, bm=64, bn=128):
    """x (m,k) f32 @ decode(w_bits) (k,n) → (m,n) f32, decode fused into the
    kernel so the weight tile is expanded HBM→VMEM once per use."""
    m, k = x.shape
    k2, n = w_bits.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w_bits)
