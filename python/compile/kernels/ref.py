"""Pure-jnp reference implementation of the b-posit32 ⟨32,6,5⟩ codec —
the correctness oracle for the Pallas kernels.

Architecturally this is the *standard posit* decode path: a leading-run
count followed by data-dependent shifts (the software analogue of the
LZC → barrel-shifter chain of the paper's Fig 10). The Pallas kernel in
bposit.py instead implements the paper's *b-posit* select-based algorithm
(Fig 12) — comparing the two bit-exactly in pytest is the same
architectural comparison the paper performs in silicon.

All functions are vectorized over int32 arrays holding the bit patterns.
"""

import jax.numpy as jnp

# ⟨n, rs, es⟩ — the paper's headline configuration.
N = 32
RS = 6
ES = 5
FW = N - 3 - ES  # fovea fraction width = 24
NAR = jnp.int32(-0x80000000)


def _u(x):
    return x.astype(jnp.uint32)


def decode_ref(bits):
    """b-posit32 bits (int32) → float32 values (sequential algorithm)."""
    u = _u(bits)
    sign = (u >> 31) & 1
    mag = jnp.where(sign == 1, (~u + 1), u) & jnp.uint32(0x7FFFFFFF)
    body = mag  # 31-bit body
    b0 = (body >> 30) & 1
    # Leading-run count via data-dependent compare loop over the cap width
    # (sequential architecture: this is a CLZ).
    x = jnp.where(b0 == 1, ~body, body) & jnp.uint32(0x7FFFFFFF)
    # Count leading zeros of x within 31 bits, capped at RS.
    run = jnp.zeros_like(u)
    for i in range(RS):  # cap bound: only RS iterations matter
        bit = (x >> (30 - i)) & 1
        run = jnp.where((run == i) & (bit == 0), i + 1, run)
    run = jnp.minimum(run, RS)
    reg_len = jnp.where(run == RS, RS, run + 1)
    r = jnp.where(b0 == 1, run.astype(jnp.int32) - 1, -run.astype(jnp.int32))
    # Data-dependent left shift aligns exp‖frac (the "barrel shifter").
    # The first exponent bit sits at position 30−reg_len; shifting left by
    # reg_len+1 brings it to bit 31 (the top of the 32-bit window).
    payload = (body << (reg_len + 1)).astype(jnp.uint32)
    e = (payload >> (32 - ES)).astype(jnp.int32)
    f = ((payload >> (32 - ES - FW)) & jnp.uint32((1 << FW) - 1)).astype(jnp.int32)
    t = r * (1 << ES) + e
    sig = 1.0 + f.astype(jnp.float32) / jnp.float32(1 << FW)
    # Kernel contract (documented in DESIGN.md): XLA CPU flushes f32
    # subnormals (FTZ/DAZ), so the f32-facing codec is defined over the
    # normal range only: t < −126 flushes to 0, t > 127 overflows to ±inf.
    val = jnp.ldexp(sig, jnp.maximum(t, -126)).astype(jnp.float32)
    val = jnp.where(t < -126, jnp.float32(0), val)
    val = jnp.where(sign == 1, -val, val)
    val = jnp.where(_u(bits) == 0, jnp.float32(0), val)
    val = jnp.where(bits == NAR, jnp.float32(jnp.nan), val)
    return val


def _rne_shift(f, d):
    """Round-to-nearest-even of f >> d (d ≥ 1), vectorized."""
    q = f >> d
    rem = f & ((1 << d) - 1)
    half = 1 << (d - 1)
    up = (rem > half) | ((rem == half) & ((q & 1) == 1))
    return q + up.astype(q.dtype)


def encode_ref(x):
    """float32 values → b-posit32 bits (int32), RNE + saturation.

    Sequential architecture: regime built with data-dependent shifts.
    """
    xf = x.astype(jnp.float32)
    sign = xf < 0
    mag = jnp.abs(xf)
    m, e2 = jnp.frexp(mag)  # mag = m·2^e2, m ∈ [0.5, 1)
    t = e2.astype(jnp.int32) - 1
    # 23-bit fraction of the significand (exact for f32 inputs).
    f23 = jnp.round((m * 2 - 1) * (1 << 23)).astype(jnp.uint32)
    r = t >> ES
    e5 = (t - (r << ES)).astype(jnp.uint32)
    # Regime field (capped) and size. All pattern math in uint32: the body
    # never exceeds 2^31, which fits.
    k = jnp.clip(jnp.where(r >= 0, r + 2, 1 - r), 2, RS)
    run_p = jnp.clip(r + 1, 0, RS).astype(jnp.uint32)  # positive-run length
    ones_run = ((jnp.uint32(1) << run_p) - 1) << 1  # terminated pattern
    reg = jnp.where(
        r >= 0,
        jnp.where(r >= RS - 1, jnp.uint32((1 << RS) - 1), ones_run),
        jnp.where(r <= -RS, jnp.uint32(0), jnp.uint32(1)),
    )
    fw = ((N - 1 - ES) - k).astype(jnp.uint32)  # 26 - k
    base = ((reg << ES) | e5) << fw
    drop = 23 - fw.astype(jnp.int32)
    frac = jnp.where(
        drop <= 0,
        f23 << jnp.maximum(-drop, 0).astype(jnp.uint32),
        _rne_shift(f23, jnp.maximum(drop, 1).astype(jnp.uint32)),
    )
    body = base + frac
    # Saturation: clamp to [1, maxpos]; out-of-range scales saturate.
    maxpos = jnp.uint32((1 << 31) - 1)
    body = jnp.where(r > RS - 1, maxpos, body)
    body = jnp.where(r < -RS, jnp.uint32(1), body)
    body = jnp.clip(body, jnp.uint32(1), maxpos)
    word = jnp.where(sign, ~body + 1, body)
    word = word.astype(jnp.int32)
    # Kernel contract: f32 subnormal inputs are flushed to zero (XLA CPU is
    # DAZ anyway; making it explicit keeps the behavior deterministic).
    word = jnp.where(mag < jnp.float32(2.0**-126), jnp.int32(0), word)
    word = jnp.where(jnp.isnan(xf) | jnp.isinf(xf), NAR, word)
    return word


def matmul_ref(x, w_bits):
    """Reference quantized matmul: decode b-posit weights, then f32 dot."""
    w = decode_ref(w_bits)
    return jnp.dot(x.astype(jnp.float32), w)
