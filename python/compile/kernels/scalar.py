"""Scalar (pure-Python integer) posit/b-posit codec — the ground-truth
oracle for the vectorized reference (ref.py) and the Pallas kernels, and
the generator of the cross-language golden vectors consumed by the Rust
test suite (rust/tests/golden_vectors.rs).

Semantics mirror rust/src/formats/posit.rs exactly:
- ⟨n, rs, es⟩ bounded posit; rs = n−1 gives the standard posit.
- 0…0 = zero, 10…0 = NaR, negatives are 2's complements.
- Regime run terminated by the opposite bit or by reaching rs bits.
- Round-to-nearest-even in pattern space with posit saturation.

Python's big ints make the bit-stream construction trivial, which is what
makes this an independent implementation rather than a port.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction


@dataclass(frozen=True)
class Spec:
    n: int
    rs: int
    es: int

    @property
    def mask(self) -> int:
        return (1 << self.n) - 1

    @property
    def nar(self) -> int:
        return 1 << (self.n - 1)

    @property
    def maxpos_body(self) -> int:
        return (1 << (self.n - 1)) - 1

    @property
    def r_max(self) -> int:
        return self.rs - 1

    @property
    def r_min(self) -> int:
        return -self.rs if self.rs < self.n - 1 else -(self.n - 2)


BP32 = Spec(32, 6, 5)
BP16 = Spec(16, 6, 5)
BP64 = Spec(64, 6, 5)
BP16_E3 = Spec(16, 6, 3)
P16 = Spec(16, 15, 2)
P32 = Spec(32, 31, 2)
P64 = Spec(64, 63, 2)


def decode(spec: Spec, bits: int) -> Fraction | None:
    """Decode a pattern to an exact rational; None encodes NaR."""
    bits &= spec.mask
    if bits == 0:
        return Fraction(0)
    if bits == spec.nar:
        return None
    sign = bits >> (spec.n - 1)
    word = (-bits) & spec.mask if sign else bits
    m = spec.n - 1
    body = word & spec.maxpos_body
    b0 = (body >> (m - 1)) & 1
    run = 1
    i = m - 2
    while i >= 0 and run < spec.rs:
        if (body >> i) & 1 == b0:
            run += 1
            i -= 1
        else:
            break
    reg_len = spec.rs if run == spec.rs else run + 1
    r = run - 1 if b0 else -run
    rem_w = m - reg_len
    rem = body & ((1 << rem_w) - 1) if rem_w > 0 else 0
    if rem_w >= spec.es:
        fw = rem_w - spec.es
        e = rem >> fw
        f = rem & ((1 << fw) - 1)
    else:
        e = rem << (spec.es - rem_w)
        fw, f = 0, 0
    t = r * (1 << spec.es) + e
    sig = Fraction(f, 1 << fw) + 1 if fw else Fraction(1)
    val = sig * Fraction(2) ** t
    return -val if sign else val


def encode(spec: Spec, x: float | Fraction) -> int:
    """Encode an exact value with pattern-space RNE + posit saturation."""
    if isinstance(x, float):
        if math.isnan(x) or math.isinf(x):
            return spec.nar
        x = Fraction(x)
    if x == 0:
        return 0
    sign = x < 0
    mag = -x if sign else x
    # T = floor(log2(mag)); Fraction-exact via bit lengths.
    t = mag.numerator.bit_length() - mag.denominator.bit_length()
    if Fraction(2) ** t > mag:
        t -= 1
    assert Fraction(2) ** t <= mag < Fraction(2) ** (t + 1)
    r = t >> spec.es
    e = t - (r << spec.es)
    if r > spec.r_max:
        body = spec.maxpos_body
    elif r < spec.r_min:
        body = 1
    else:
        # Build the bit stream regime ‖ exp ‖ fraction with enough fraction
        # bits for an exact rounding decision, as a big int + exactness flag.
        if r >= 0:
            run = r + 1
            reg_bits, reg_len = (
                ((1 << spec.rs) - 1, spec.rs) if run >= spec.rs else ((((1 << run) - 1) << 1), run + 1)
            )
        else:
            run = -r
            reg_bits, reg_len = ((0, spec.rs) if run >= spec.rs else (1, run + 1))
        m = spec.n - 1
        # fraction as exact rational in [0,1)
        frac = mag / Fraction(2) ** t - 1
        # Stream value = reg ‖ e ‖ frac; cut at m bits.
        head = (reg_bits << spec.es) | e
        head_len = reg_len + spec.es
        if head_len >= m:
            keep_head = head >> (head_len - m)
            # Rounding bit: next bit of head or first frac bit.
            if head_len == m:
                g = 1 if frac >= Fraction(1, 2) else 0
                rest = frac - Fraction(1, 2) * g
                sticky = rest != 0
            else:
                g = (head >> (head_len - m - 1)) & 1
                below = head & ((1 << (head_len - m - 1)) - 1)
                sticky = below != 0 or frac != 0
            body = keep_head + (1 if g and (sticky or keep_head & 1) else 0)
        else:
            fw = m - head_len
            scaled = frac * (1 << fw)
            fint = int(scaled)  # floor
            rem = scaled - fint
            body_floor = (head << fw) | fint
            if rem > Fraction(1, 2) or (rem == Fraction(1, 2) and body_floor & 1):
                body = body_floor + 1
            else:
                body = body_floor
        if body >> m:
            body = spec.maxpos_body
        if body == 0:
            body = 1
        if body > spec.maxpos_body:
            body = spec.maxpos_body
    return (-body) & spec.mask if sign else body


def decode_f64(spec: Spec, bits: int) -> float:
    """Decode to float64 (round-to-nearest; NaR → nan)."""
    v = decode(spec, bits)
    if v is None:
        return float("nan")
    # Fraction → float is correctly rounded in CPython.
    return float(v)
