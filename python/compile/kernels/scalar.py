"""Scalar (pure-Python integer) posit/b-posit codec — the ground-truth
oracle for the vectorized reference (ref.py) and the Pallas kernels, and
the generator of the cross-language golden vectors consumed by the Rust
test suite (rust/tests/golden_vectors.rs).

Semantics mirror rust/src/formats/posit.rs exactly:
- ⟨n, rs, es⟩ bounded posit; rs = n−1 gives the standard posit.
- 0…0 = zero, 10…0 = NaR, negatives are 2's complements.
- Regime run terminated by the opposite bit or by reaching rs bits.
- Round-to-nearest-even in pattern space with posit saturation.

Python's big ints make the bit-stream construction trivial, which is what
makes this an independent implementation rather than a port.
"""

from __future__ import annotations

import math
import struct
from dataclasses import dataclass
from fractions import Fraction


@dataclass(frozen=True)
class Spec:
    n: int
    rs: int
    es: int

    @property
    def mask(self) -> int:
        return (1 << self.n) - 1

    @property
    def nar(self) -> int:
        return 1 << (self.n - 1)

    @property
    def maxpos_body(self) -> int:
        return (1 << (self.n - 1)) - 1

    @property
    def r_max(self) -> int:
        return self.rs - 1

    @property
    def r_min(self) -> int:
        return -self.rs if self.rs < self.n - 1 else -(self.n - 2)


BP32 = Spec(32, 6, 5)
BP16 = Spec(16, 6, 5)
BP64 = Spec(64, 6, 5)
BP16_E3 = Spec(16, 6, 3)
P16 = Spec(16, 15, 2)
P32 = Spec(32, 31, 2)
P64 = Spec(64, 63, 2)


def decode(spec: Spec, bits: int) -> Fraction | None:
    """Decode a pattern to an exact rational; None encodes NaR."""
    bits &= spec.mask
    if bits == 0:
        return Fraction(0)
    if bits == spec.nar:
        return None
    sign = bits >> (spec.n - 1)
    word = (-bits) & spec.mask if sign else bits
    m = spec.n - 1
    body = word & spec.maxpos_body
    b0 = (body >> (m - 1)) & 1
    run = 1
    i = m - 2
    while i >= 0 and run < spec.rs:
        if (body >> i) & 1 == b0:
            run += 1
            i -= 1
        else:
            break
    reg_len = spec.rs if run == spec.rs else run + 1
    r = run - 1 if b0 else -run
    rem_w = m - reg_len
    rem = body & ((1 << rem_w) - 1) if rem_w > 0 else 0
    if rem_w >= spec.es:
        fw = rem_w - spec.es
        e = rem >> fw
        f = rem & ((1 << fw) - 1)
    else:
        e = rem << (spec.es - rem_w)
        fw, f = 0, 0
    t = r * (1 << spec.es) + e
    sig = Fraction(f, 1 << fw) + 1 if fw else Fraction(1)
    val = sig * Fraction(2) ** t
    return -val if sign else val


def encode(spec: Spec, x: float | Fraction) -> int:
    """Encode an exact value with pattern-space RNE + posit saturation."""
    if isinstance(x, float):
        if math.isnan(x) or math.isinf(x):
            return spec.nar
        x = Fraction(x)
    if x == 0:
        return 0
    sign = x < 0
    mag = -x if sign else x
    # T = floor(log2(mag)); Fraction-exact via bit lengths.
    t = mag.numerator.bit_length() - mag.denominator.bit_length()
    if Fraction(2) ** t > mag:
        t -= 1
    assert Fraction(2) ** t <= mag < Fraction(2) ** (t + 1)
    r = t >> spec.es
    e = t - (r << spec.es)
    if r > spec.r_max:
        body = spec.maxpos_body
    elif r < spec.r_min:
        body = 1
    else:
        # Build the bit stream regime ‖ exp ‖ fraction with enough fraction
        # bits for an exact rounding decision, as a big int + exactness flag.
        if r >= 0:
            run = r + 1
            reg_bits, reg_len = (
                ((1 << spec.rs) - 1, spec.rs) if run >= spec.rs else ((((1 << run) - 1) << 1), run + 1)
            )
        else:
            run = -r
            reg_bits, reg_len = ((0, spec.rs) if run >= spec.rs else (1, run + 1))
        m = spec.n - 1
        # fraction as exact rational in [0,1)
        frac = mag / Fraction(2) ** t - 1
        # Stream value = reg ‖ e ‖ frac; cut at m bits.
        head = (reg_bits << spec.es) | e
        head_len = reg_len + spec.es
        if head_len >= m:
            keep_head = head >> (head_len - m)
            # Rounding bit: next bit of head or first frac bit.
            if head_len == m:
                g = 1 if frac >= Fraction(1, 2) else 0
                rest = frac - Fraction(1, 2) * g
                sticky = rest != 0
            else:
                g = (head >> (head_len - m - 1)) & 1
                below = head & ((1 << (head_len - m - 1)) - 1)
                sticky = below != 0 or frac != 0
            body = keep_head + (1 if g and (sticky or keep_head & 1) else 0)
        else:
            fw = m - head_len
            scaled = frac * (1 << fw)
            fint = int(scaled)  # floor
            rem = scaled - fint
            body_floor = (head << fw) | fint
            if rem > Fraction(1, 2) or (rem == Fraction(1, 2) and body_floor & 1):
                body = body_floor + 1
            else:
                body = body_floor
        if body >> m:
            body = spec.maxpos_body
        if body == 0:
            body = 1
        if body > spec.maxpos_body:
            body = spec.maxpos_body
    return (-body) & spec.mask if sign else body


def decode_f64(spec: Spec, bits: int) -> float:
    """Decode to float64 (round-to-nearest; NaR → nan)."""
    v = decode(spec, bits)
    if v is None:
        return float("nan")
    # Fraction → float is correctly rounded in CPython.
    return float(v)


# ----------------------------------------------------------------------
# f64-facing contract layer (the vector lane codec's semantics)
# ----------------------------------------------------------------------
#
# The Rust 64-bit lane codec (rust/src/vector/codec64.rs) exposes posit
# patterns through f64 streams under a fixed contract:
# - encode: f64 subnormal inputs (|x| < 2^-1022) quantize to 0 (FTZ/DAZ),
#   NaN/Inf → NaR;
# - decode: values whose 52-bit-rounded scale falls below the f64 normal
#   range flush to ±0 (keeping the sign), values above it saturate to ±inf,
#   NaR → canonical quiet NaN.
#
# For every lane-supported spec (n ≤ 64, es ≥ 1) the fraction width near
# the f64 range boundaries is ≤ 52 bits, so "round exactly to f64, then
# flush subnormals / saturate" is identical to the lane algorithm's
# "round the fraction to 52 bits, then test the scale" — which is what
# lets the big-int oracle below stay independent of the bit-level stream
# construction.

F64_MIN_NORMAL = 2.0**-1022


def f64_to_bits(x: float) -> int:
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def bits_to_f64(b: int) -> float:
    return struct.unpack("<d", struct.pack("<Q", b & ((1 << 64) - 1)))[0]


def encode_f64_contract(spec: Spec, x: float) -> int:
    """Big-int-oracle encode of an f64 under the lane-codec contract."""
    if math.isnan(x) or math.isinf(x):
        return spec.nar
    if x == 0.0 or abs(x) < F64_MIN_NORMAL:
        return 0  # FTZ/DAZ: f64 subnormals quantize to the zero pattern
    return encode(spec, Fraction(x))


def decode_f64_contract(spec: Spec, bits: int) -> float:
    """Big-int-oracle decode to f64 under the lane-codec contract."""
    v = decode(spec, bits)
    if v is None:
        return float("nan")
    if v == 0:
        return 0.0
    try:
        f = float(v)  # correctly rounded in CPython
    except OverflowError:
        f = math.inf if v > 0 else -math.inf
    if f != 0.0 and abs(f) < F64_MIN_NORMAL:
        return -0.0 if f < 0 else 0.0  # flush below the f64 normal range
    return f


# ----------------------------------------------------------------------
# Branch-free lane-codec mirror (the algorithm ported to Rust)
# ----------------------------------------------------------------------
#
# `lane_encode`/`lane_decode` mirror rust/src/vector/codec64.rs exactly:
# u64 words, u128 intermediate streams (emulated here by masking big
# ints), pure value selects, one pattern-space RNE cut. They are the
# *implementation under test*; `encode_f64_contract`/`decode_f64_contract`
# above are the independent ground truth (Fraction arithmetic, loopy
# regime scan — no shared structure). test_scalar_oracle64.py and the
# PR-time validation sweeps prove them equal on every lane-supported spec.

_M64 = (1 << 64) - 1
_M128 = (1 << 128) - 1


def lane_supported(spec: Spec) -> bool:
    """Specs covered by the 64-bit lane codec (and this mirror)."""
    return 3 <= spec.n <= 64 and 2 <= spec.rs <= spec.n - 1 and 1 <= spec.es <= 8


def lane_encode(spec: Spec, x: float) -> int:
    """Branch-free encode mirror: f64 → n-bit posit word (see contract)."""
    assert lane_supported(spec)
    n, rs, es = spec.n, spec.rs, spec.es
    m = n - 1
    mask_n = (1 << n) - 1
    maxpos = (1 << m) - 1
    bounded = rs < m
    r_max = rs - 1
    r_min = -rs if bounded else -(n - 2)

    bits = f64_to_bits(x)
    sign = bits >> 63
    biased = (bits >> 52) & 0x7FF
    f52 = bits & ((1 << 52) - 1)
    if biased == 0x7FF:
        return spec.nar  # NaN/Inf → NaR
    if biased == 0:
        return 0  # zero and FTZ'd subnormals
    t = biased - 1023
    r = t >> es  # floor(t / 2^es)
    e = t & ((1 << es) - 1)
    sat_hi = r > r_max
    sat_lo = r < r_min
    rc = min(max(r, r_min), r_max)
    run = rc + 1 if rc >= 0 else -rc
    capped = run >= rs
    w_reg = rs if capped else run + 1
    reg_ones = (1 << w_reg) - 1
    reg_val = (reg_ones - (0 if capped else 1)) if rc >= 0 else (0 if capped else 1)
    # Serialize regime ‖ exponent ‖ fraction MSB-first into a u128 stream
    # (w_reg + es + 52 ≤ 63 + 8 + 52 = 123 bits: shifts never underflow).
    sh_reg = 128 - w_reg
    sh_exp = sh_reg - es
    sh_frac = sh_exp - 52
    s = ((reg_val << sh_reg) | (e << sh_exp) | (f52 << sh_frac)) & _M128
    # Cut at m bits with round-to-nearest-even: rem+lsb>half ⟺ RNE up.
    cut = 128 - m  # 65..=126
    q = s >> cut
    rem = s & ((1 << cut) - 1)
    half = 1 << (cut - 1)
    up = 1 if rem + (q & 1) > half else 0
    body = max(min(q + up, maxpos), 1)
    if sat_hi:
        body = maxpos
    if sat_lo:
        body = 1
    return (-body) & mask_n if sign else body


def lane_decode(spec: Spec, word: int) -> float:
    """Branch-free decode mirror: n-bit posit word → f64 (see contract)."""
    assert lane_supported(spec)
    n, rs, es = spec.n, spec.rs, spec.es
    m = n - 1
    body_mask = (1 << m) - 1
    word &= spec.mask
    if word == 0:
        return 0.0
    if word == spec.nar:
        return float("nan")
    sign = (word >> m) & 1
    mag = ((-word) if sign else word) & body_mask
    b0 = (mag >> (m - 1)) & 1
    # Leading-run length within the m-bit body, capped at rs.
    probe = ((~mag) if b0 else mag) & body_mask
    p64 = (probe << (64 - m)) & _M64
    lz = 64 - p64.bit_length()  # u64 leading_zeros (probe == 0 ⇒ 64 ≥ m)
    run = min(lz, m, rs)
    reg_len = run + (1 if run != rs else 0)  # +terminator unless capped
    r = run - 1 if b0 else -run
    # Align the first post-regime bit to bit 127 of a u128 (two-step shift
    # keeps the amount ≤ 127 even when reg_len = m). Ghost exponent bits
    # and the empty fraction fall out as zeros automatically.
    pay = ((mag << (127 - m + reg_len)) << 1) & _M128
    e = pay >> (128 - es)
    frac_top = (pay << es) & _M128  # fraction, MSB-aligned at bit 127
    t = r * (1 << es) + e
    # RNE the (≤ 60-bit) fraction to 52 f64 bits; guard/sticky live in the
    # low 76 bits of frac_top.
    q = frac_top >> 76
    rem = frac_top & ((1 << 76) - 1)
    up = 1 if rem + (q & 1) > (1 << 75) else 0
    frac = q + up
    tt = t + (frac >> 52)  # rounding carry bumps the scale
    frac &= (1 << 52) - 1
    if tt < -1022:
        fbits = sign << 63  # FTZ contract (keeps the sign)
    elif tt > 1023:
        fbits = (sign << 63) | (0x7FF << 52)  # ±inf
    else:
        fbits = (sign << 63) | ((tt + 1023) << 52) | frac
    return bits_to_f64(fbits)
