"""Layer 2: the JAX model — an MLP classifier whose weights are stored as
b-posit32 words and decoded in-graph by the Pallas kernel (the paper's
format used as a first-class model dtype).

Two forward variants are AOT-compiled for the Rust runtime:
- `forward_f32`: plain float32 reference.
- `forward_bposit`: weight matrices arrive as int32 b-posit words; each
  layer runs the fused decode+matmul Pallas kernel.

`train` fits the f32 model on a synthetic 16-class Gaussian-blob task at
build time (Python never touches the request path), producing real
weights for the artifacts.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bposit, ref

# Model dimensions: D-dim features → H hidden → C classes.
D, H, C = 64, 128, 16
BATCH = 64


def init_params(seed: int = 0):
    """He-initialized MLP parameters."""
    rng = np.random.RandomState(seed)
    return {
        "w1": jnp.asarray(rng.randn(D, H).astype(np.float32) * np.sqrt(2.0 / D)),
        "b1": jnp.zeros((H,), jnp.float32),
        "w2": jnp.asarray(rng.randn(H, C).astype(np.float32) * np.sqrt(2.0 / H)),
        "b2": jnp.zeros((C,), jnp.float32),
    }


def forward_f32(params, x):
    """Reference f32 forward pass."""
    h = jnp.maximum(x @ params["w1"] + params["b1"], 0.0)
    return h @ params["w2"] + params["b2"]


def forward_bposit(x, w1_bits, b1, w2_bits, b2):
    """Quantized forward: weights decoded from b-posit32 inside the Pallas
    matmul kernels."""
    h = jnp.maximum(bposit.matmul(x, w1_bits) + b1, 0.0)
    return bposit.matmul(h, w2_bits, bm=64, bn=16) + b2


def quantize_params(params):
    """Encode both weight matrices to b-posit32 words (int32)."""
    w1_bits = bposit.encode(params["w1"].reshape(-1)).reshape(D, H)
    w2_bits = bposit.encode(params["w2"].reshape(-1)).reshape(H, C)
    return w1_bits, w2_bits


def make_dataset(seed: int = 1, per_class: int = 64):
    """Synthetic 16-class Gaussian blobs in D dimensions."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(C, D).astype(np.float32) * 2.0
    xs, ys = [], []
    for c in range(C):
        xs.append(centers[c] + rng.randn(per_class, D).astype(np.float32))
        ys.append(np.full(per_class, c, dtype=np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return jnp.asarray(x[perm]), jnp.asarray(y[perm])


def loss_fn(params, x, y):
    logits = forward_f32(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


def train(steps: int = 300, lr: float = 0.05, seed: int = 0):
    """Full-batch gradient descent; returns (params, history, accuracy)."""
    params = init_params(seed)
    x, y = make_dataset(seed + 1)
    grad = jax.jit(jax.grad(loss_fn))
    lossj = jax.jit(loss_fn)
    history = []
    for step in range(steps):
        g = grad(params, x, y)
        params = {k: params[k] - lr * g[k] for k in params}
        if step % 20 == 0 or step == steps - 1:
            history.append((step, float(lossj(params, x, y))))
    logits = forward_f32(params, x)
    acc = float(jnp.mean(jnp.argmax(logits, axis=1) == y))
    return params, history, acc


def quantized_accuracy(params, x, y):
    """Accuracy of the b-posit-quantized model (Pallas path)."""
    w1_bits, w2_bits = quantize_params(params)
    n = (x.shape[0] // BATCH) * BATCH
    correct = 0
    for i in range(0, n, BATCH):
        logits = forward_bposit(x[i : i + BATCH], w1_bits, params["b1"], w2_bits, params["b2"])
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == y[i : i + BATCH]))
    return correct / n


def export_weights(params, path, data_seed=1):
    """Dump weights (f32 + b-posit32 words) and golden vectors as JSON.

    The golden batch is drawn from the training distribution (same class
    centers, fresh noise) so the recorded logits/labels are a meaningful
    accuracy fixture for the Rust serving path."""
    w1_bits, w2_bits = quantize_params(params)
    x, y = make_dataset(seed=data_seed, per_class=4)
    x = x[:BATCH]
    y = y[:BATCH]
    golden_f32 = forward_f32(params, x)
    golden_bp = forward_bposit(x, w1_bits, params["b1"], w2_bits, params["b2"])
    blob = {
        "d": D,
        "h": H,
        "c": C,
        "batch": BATCH,
        "w1": np.asarray(params["w1"]).reshape(-1).tolist(),
        "b1": np.asarray(params["b1"]).tolist(),
        "w2": np.asarray(params["w2"]).reshape(-1).tolist(),
        "b2": np.asarray(params["b2"]).tolist(),
        "w1_bits": np.asarray(w1_bits).reshape(-1).tolist(),
        "w2_bits": np.asarray(w2_bits).reshape(-1).tolist(),
        "golden_x": np.asarray(x).reshape(-1).tolist(),
        "golden_y": np.asarray(y).tolist(),
        "golden_logits_f32": np.asarray(golden_f32).reshape(-1).tolist(),
        "golden_logits_bposit": np.asarray(golden_bp).reshape(-1).tolist(),
    }
    with open(path, "w") as f:
        json.dump(blob, f)
    return blob


def _ref_forward_bposit(x, w1_bits, b1, w2_bits, b2):
    """Oracle for the quantized forward (pure jnp, sequential decode)."""
    h = jnp.maximum(ref.matmul_ref(x, w1_bits) + b1, 0.0)
    return ref.matmul_ref(h, w2_bits) + b2
