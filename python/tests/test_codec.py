"""Scalar-oracle and vectorized-reference codec tests (hypothesis-driven)."""

import math
from fractions import Fraction

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, scalar

SPECS = [scalar.P16, scalar.P32, scalar.BP16, scalar.BP32, scalar.BP64, scalar.BP16_E3]


# ----------------------------------------------------------------------
# Scalar oracle self-consistency
# ----------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"n{s.n}rs{s.rs}es{s.es}")
@given(bits=st.integers(min_value=0, max_value=(1 << 64) - 1))
@settings(max_examples=300, deadline=None)
def test_scalar_roundtrip(spec, bits):
    bits &= spec.mask
    v = scalar.decode(spec, bits)
    if v is None:  # NaR
        assert bits == spec.nar
        return
    back = scalar.encode(spec, v)
    assert back == bits, f"roundtrip failed for {bits:#x}"


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"n{s.n}rs{s.rs}es{s.es}")
def test_scalar_monotonic_sampled(spec):
    # Patterns ordered as 2's-complement ints must decode to ordered values.
    import random

    random.seed(5)
    pats = sorted({random.getrandbits(spec.n) for _ in range(500)} - {spec.nar})
    vals = []
    for raw in pats:
        signed = raw - (1 << spec.n) if raw >> (spec.n - 1) else raw
        vals.append((signed, scalar.decode(spec, raw)))
    vals = [(s, v) for s, v in sorted(vals) if v is not None]
    for (s1, v1), (s2, v2) in zip(vals, vals[1:]):
        if s1 == s2:
            continue
        assert v1 < v2, f"non-monotonic at {s1} vs {s2}"


def test_scalar_known_values():
    assert scalar.encode(scalar.P16, 1.0) == 0x4000
    assert scalar.decode(scalar.P16, 0x4C91) == Fraction(3217, 1024)  # π ≈ 3.1416015625
    assert scalar.encode(scalar.BP32, 0.0) == 0
    assert scalar.encode(scalar.BP32, float("nan")) == 0x80000000
    assert scalar.decode(scalar.BP32, 1) == Fraction(2**20 + 1, 2**20) * Fraction(2) ** -192


def test_scalar_saturation():
    assert scalar.encode(scalar.BP32, 1e300) == 0x7FFFFFFF
    assert scalar.encode(scalar.BP32, -1e300) == 0x80000001
    assert scalar.encode(scalar.BP32, 1e-300) == 1
    assert scalar.encode(scalar.P16, 1e300) == 0x7FFF


def test_scalar_dynamic_range_matches_paper():
    # ⟨32,6,5⟩ spans 2^-192 … ~2^192.
    maxv = scalar.decode(scalar.BP32, scalar.BP32.maxpos_body)
    assert Fraction(2) ** 191 <= maxv < Fraction(2) ** 192
    minv = scalar.decode(scalar.BP32, 1)
    assert Fraction(2) ** -192 < minv < Fraction(2) ** -191


# ----------------------------------------------------------------------
# Vectorized reference vs scalar oracle
# ----------------------------------------------------------------------

@given(bits=st.lists(st.integers(min_value=0, max_value=0xFFFFFFFF), min_size=1, max_size=64))
@settings(max_examples=150, deadline=None)
def test_ref_decode_matches_scalar(bits):
    arr = jnp.asarray(np.array(bits, dtype=np.uint64).astype(np.uint32).view(np.int32))
    got = np.array(ref.decode_ref(arr))
    for p, g in zip(bits, got):
        want = scalar.decode_f64(scalar.BP32, p)
        if math.isnan(want):
            assert math.isnan(g)
            continue
        w32 = np.float32(want) if abs(want) < 1e39 else np.float32(np.inf) * np.sign(want)
        if w32 != 0 and abs(w32) < 2.0**-126:
            assert g == 0 or g == w32  # flush contract
        else:
            assert g == w32, f"{p:#x}: got {g}, want {w32}"


@given(
    xs=st.lists(
        st.floats(
            min_value=-3.3999999521443642e38,
            max_value=3.3999999521443642e38,
            allow_nan=False,
            width=32,
        ),
        min_size=1,
        max_size=64,
    )
)
@settings(max_examples=150, deadline=None)
def test_ref_encode_matches_scalar(xs):
    arr = jnp.asarray(np.array(xs, dtype=np.float32))
    got = np.array(ref.encode_ref(arr)).view(np.uint32)
    for v, g in zip(np.array(xs, dtype=np.float32), got):
        v = float(v)
        if v != 0 and abs(v) < 2.0**-126:
            assert int(g) == 0  # flush contract
        else:
            want = scalar.encode(scalar.BP32, v)
            assert int(g) == want, f"{v}: got {int(g):#x}, want {want:#x}"


def test_ref_decode_specials():
    bits = jnp.asarray(np.array([0, 0x80000000, 0x40000000, 0xC0000000], dtype=np.uint32).view(np.int32))
    out = np.array(ref.decode_ref(bits))
    assert out[0] == 0.0
    assert math.isnan(out[1])
    assert out[2] == 1.0
    assert out[3] == -1.0


def test_ref_encode_exact_in_fovea():
    # Fovea carries 24 fraction bits ≥ f32's 23: every normal f32 in
    # [2^-32, 2^32) must round-trip exactly.
    rng = np.random.RandomState(0)
    xs = (rng.randn(4096).astype(np.float32) * rng.uniform(0.001, 1000, 4096).astype(np.float32))
    xs = xs[np.abs(xs) > 2.0**-32]
    enc = ref.encode_ref(jnp.asarray(xs))
    dec = np.array(ref.decode_ref(enc))
    assert np.array_equal(dec, xs[: len(dec)])
