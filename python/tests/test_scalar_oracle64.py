"""Oracle-differential tests for the 64-bit lane-codec mirror
(compile/kernels/scalar.py: lane_encode/lane_decode) against the
independent big-int oracle (encode/decode + the f64 contract layer) —
pure stdlib, so they run in the bare-interpreter CI job.

The mirror is the algorithm ported verbatim to rust/src/vector/codec64.rs
(u64 words, u128 streams); the oracle is Fraction arithmetic with a loopy
regime scan. Agreement here is what licenses the Rust transliteration.

Coverage per the ISSUE-3 satellite:
- exhaustive 16-bit cross-check of the generic path (two (rs, es) corners);
- stratified ≥300k-sample sweeps for BP64 and P64 (decode over stratified
  bit patterns, encode over the same bits as f64 values);
- boundary strata: ±maxpos, ±minpos, regime saturation at every power of
  two across the f64 range, f64-subnormal FTZ, NaN/Inf → NaR, and
  pattern-space RNE ties.
"""

import math
import random
from fractions import Fraction

from compile.kernels import scalar


def _assert_dec(sp, w):
    ld = scalar.lane_decode(sp, w)
    od = scalar.decode_f64_contract(sp, w)
    if math.isnan(od):
        assert math.isnan(ld), (sp, hex(w))
    else:
        assert scalar.f64_to_bits(ld) == scalar.f64_to_bits(od), (sp, hex(w), ld, od)


def _assert_enc(sp, x):
    le = scalar.lane_encode(sp, x)
    oe = scalar.encode_f64_contract(sp, x)
    assert le == oe, (sp, repr(x), hex(le), hex(oe))


def _exhaustive_16(sp):
    for w in range(1 << 16):
        _assert_dec(sp, w)
        v = scalar.decode_f64_contract(sp, w)
        if not math.isnan(v) and v != 0.0 and not math.isinf(v):
            _assert_enc(sp, v)
        # Pattern-midpoint RNE ties (representable whenever the short
        # 16-bit fraction field leaves the midpoint ≤ 53 significant bits).
        v1 = scalar.decode(sp, w)
        v2 = scalar.decode(sp, (w + 1) & sp.mask)
        if v1 is not None and v2 is not None:
            mid = (v1 + v2) / 2
            f = float(mid)
            if (Fraction(f) == mid and abs(f) >= scalar.F64_MIN_NORMAL
                    and not math.isinf(f)):
                _assert_enc(sp, f)
    rng = random.Random(sp.rs * 256 + sp.es)
    for _ in range(20000):
        _assert_enc(sp, scalar.bits_to_f64(rng.getrandbits(64)))


def test_exhaustive_16bit_bounded():
    _exhaustive_16(scalar.Spec(16, 6, 5))  # the paper's b-posit config


def test_exhaustive_16bit_standard():
    _exhaustive_16(scalar.Spec(16, 15, 2))  # standard-posit regime rule


def _stratified_64(sp, log2_strata=18):
    # One decode + one encode sample per stratum of the top bits, with
    # random low bits: ≥ 2·2^18 > 500k oracle comparisons per spec.
    rng = random.Random(0x64 + sp.rs)
    shift = 64 - log2_strata
    for stratum in range(1 << log2_strata):
        w = (stratum << shift) | rng.getrandbits(shift)
        _assert_dec(sp, w)
        _assert_enc(sp, scalar.bits_to_f64(w))


def test_bp64_stratified_sweep():
    _stratified_64(scalar.BP64)


def test_p64_stratified_sweep():
    _stratified_64(scalar.P64)


def test_boundary_strata():
    for sp in (scalar.BP64, scalar.P64):
        nar, mask = sp.nar, sp.mask
        # ±maxpos, ±minpos, NaR neighbours, fovea edges.
        for w in [0, 1, 2, 3, nar, mask, sp.maxpos_body, nar + 1, nar - 1,
                  mask - 1, 1 << (sp.n - 2), (1 << (sp.n - 2)) - 1]:
            _assert_dec(sp, w & mask)
        # f64-subnormal FTZ, NaN/Inf → NaR, format-range edges.
        for v in [0.0, -0.0, 5e-324, -5e-324, 2.0**-1022, -(2.0**-1022),
                  float("nan"), float("inf"), -float("inf"), 1e308, -1e308,
                  2.0**191, 2.0**192, 2.0**-192, 2.0**-193, 2.0**1023]:
            _assert_enc(sp, v)
        assert scalar.lane_encode(sp, float("nan")) == nar
        assert scalar.lane_encode(sp, float("inf")) == nar
        assert scalar.lane_encode(sp, 5e-324) == 0  # FTZ stratum
        # Regime saturation: every power of two across the f64 range (hits
        # sat_hi/sat_lo for both the rs=6 bound and the standard regime).
        for t in range(-1022, 1024):
            _assert_enc(sp, 2.0**t)
            _assert_enc(sp, -(2.0**t))
            _assert_enc(sp, 1.9999999 * 2.0**t)


def test_pattern_space_rne_ties_p64():
    # Midpoints of adjacent patterns, exactly representable as f64,
    # exercise the tie-to-even select in the lane encode. Representable
    # midpoints need a fraction field ≤ 52 bits, which for posit⟨64,2⟩
    # means a regime run of ≥ 9 — so construct long-regime words directly
    # instead of fishing for them in random patterns.
    sp = scalar.P64
    rng = random.Random(7)
    ties = 0
    for run in range(9, 61):
        fw = 60 - run  # explicit fraction bits at this regime size
        base = scalar.encode(sp, Fraction(2) ** (-run * 4))  # run zeros
        for _ in range(12):
            w = base + (rng.getrandbits(fw) if fw else 0)
            v1, v2 = scalar.decode(sp, w), scalar.decode(sp, w + 1)
            mid = (v1 + v2) / 2
            f = float(mid)
            assert Fraction(f) == mid, hex(w)  # fw+1 ≤ 53 ⇒ exact
            _assert_enc(sp, f)
            ties += 1
    assert ties >= 400


def test_bp64_f64_grid_is_exact():
    # ⟨64,6,5⟩ carries ≥ 52 fraction bits at every scale, so *every*
    # f64 in the format's range is exactly representable: encode never
    # rounds, and decode∘encode is the identity on in-range f64s. (This
    # is also why pattern-midpoint RNE ties cannot occur for BP64.)
    sp = scalar.BP64
    rng = random.Random(11)
    for _ in range(20000):
        x = scalar.bits_to_f64(rng.getrandbits(64))
        if math.isnan(x) or math.isinf(x) or x == 0.0:
            continue
        if not (2.0**-192 <= abs(x) < 2.0**191):
            continue
        w = scalar.lane_encode(sp, x)
        back = scalar.lane_decode(sp, w)
        assert scalar.f64_to_bits(back) == scalar.f64_to_bits(x), (repr(x), hex(w))


def test_lane_known_patterns():
    bp, p = scalar.BP64, scalar.P64
    assert scalar.lane_encode(bp, 1.0) == 0x4000000000000000
    assert scalar.lane_encode(bp, -1.0) == 0xC000000000000000
    assert scalar.lane_decode(bp, 0x4000000000000000) == 1.0
    assert scalar.lane_decode(p, 0x4000000000000000) == 1.0
    # b-posit64 maxpos: scale 2^191 with a 52-bit-truncated fraction.
    assert scalar.lane_decode(bp, bp.maxpos_body) == scalar.decode_f64_contract(
        bp, bp.maxpos_body
    )
    # p64 minpos = 2^-248 — within f64 range, must NOT flush.
    assert scalar.lane_decode(p, 1) == 2.0**-248
