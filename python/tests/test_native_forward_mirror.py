"""Mirror of the Rust native serving backend's forward pass
(rust/src/coordinator/backend.rs) against the scalar reference
(`reference_forward`), pure stdlib.

The native backend runs the two-layer MLP in a *transposed* layout so the
quantized-weight GEMM can keep weights as the A matrix:

    Xᵀ (d×rows)   staged per batch
    H  (h×rows) = W1ᵀ (h×d) · Xᵀ      then per-row bias + ReLU
    L  (c×rows) = W2ᵀ (c×h) · H       then per-row bias
    out[j][q]   = L[q][j]             readout back to request-major

The scalar reference computes each request independently:

    hid[i] = relu(Σ_p w1[p·h+i]·x[p] + b1[i])   (ascending p)
    out[q] = Σ_i w2[i·c+q]·hid[i] + b2[q]       (ascending i)

Both are implemented here with the *exact* index formulas of the Rust
code and compared for exact float equality: every output element is the
same ascending-index accumulation chain in both formulations (the Rust
blocked GEMM is bitwise-identical to the naive triple loop — proven by
rust/tests/vector_gemm.rs — so naive GEMM is the faithful mirror), and
Python floats make any index slip or reassociation show up as a hard
inequality.

ReLU is mirrored as `v if v > 0.0 else 0.0` — the explicit select the
Rust side uses (not `max`, whose −0.0 behavior is platform-defined).
"""

import random
import unittest


def reference_forward(w1, b1, w2, b2, x, d, h, c):
    """Per-request scalar forward (mirrors backend.rs::reference_forward)."""
    hid = []
    for i in range(h):
        acc = 0.0
        for p in range(d):
            acc += w1[p * h + i] * x[p]
        v = acc + b1[i]
        hid.append(v if v > 0.0 else 0.0)
    out = []
    for q in range(c):
        acc = 0.0
        for i in range(h):
            acc += w2[i * c + q] * hid[i]
        out.append(acc + b2[q])
    return out


def transpose(src, rows, cols):
    """dst (cols×rows) ← src (rows×cols), mirrors vector::gemm::transpose."""
    dst = [0.0] * (rows * cols)
    for i in range(rows):
        for j in range(cols):
            dst[j * rows + i] = src[i * cols + j]
    return dst


def naive_gemm(a, b, m, k, n):
    """C (m×n) = A (m×k) · B (k×n), one ascending-p chain per element —
    the accumulation order the Rust blocked GEMM provably reproduces."""
    cm = [0.0] * (m * n)
    for i in range(m):
        for j in range(n):
            acc = 0.0
            for p in range(k):
                acc += a[i * k + p] * b[p * n + j]
            cm[i * n + j] = acc
    return cm


def native_forward(w1, b1, w2, b2, xs, rows, d, h, c):
    """Batch forward in the transposed layout (mirrors NativeBackend::run)."""
    wt1 = transpose(w1, d, h)  # h×d
    wt2 = transpose(w2, h, c)  # c×h
    xt = transpose(xs, rows, d)  # d×rows
    ht = naive_gemm(wt1, xt, h, d, rows)
    for i in range(h):  # bias_relu_rows
        for j in range(rows):
            v = ht[i * rows + j] + b1[i]
            ht[i * rows + j] = v if v > 0.0 else 0.0
    lt = naive_gemm(wt2, ht, c, h, rows)
    for q in range(c):  # bias_rows
        for j in range(rows):
            lt[q * rows + j] += b2[q]
    out = [0.0] * (rows * c)
    for q in range(c):  # readout transpose
        for j in range(rows):
            out[j * c + q] = lt[q * rows + j]
    return out


class NativeForwardMirror(unittest.TestCase):
    def test_transposed_batch_equals_per_request_reference_exactly(self):
        rng = random.Random(0x5E47)
        for d, h, c, rows in [(1, 1, 1, 1), (5, 7, 3, 4), (8, 16, 4, 1), (16, 24, 8, 33)]:
            w1 = [rng.uniform(-0.5, 0.5) for _ in range(d * h)]
            b1 = [rng.uniform(-0.2, 0.2) for _ in range(h)]
            w2 = [rng.uniform(-0.5, 0.5) for _ in range(h * c)]
            b2 = [rng.uniform(-0.2, 0.2) for _ in range(c)]
            xs = [rng.uniform(-2.0, 2.0) for _ in range(rows * d)]
            got = native_forward(w1, b1, w2, b2, xs, rows, d, h, c)
            for g in range(rows):
                want = reference_forward(w1, b1, w2, b2, xs[g * d : (g + 1) * d], d, h, c)
                self.assertEqual(
                    got[g * c : (g + 1) * c],
                    want,
                    f"d={d} h={h} c={c} rows={rows} row {g}: exact mismatch",
                )

    def test_relu_select_handles_negative_zero_and_dead_units(self):
        # A unit whose pre-activation is exactly 0.0 or negative must
        # emit +0.0 through both formulations.
        d, h, c, rows = 2, 2, 1, 2
        w1 = [1.0, -1.0, -1.0, 1.0]
        b1 = [0.0, -10.0]
        w2 = [0.5, 0.25]
        b2 = [0.125]
        xs = [1.0, 1.0, 0.5, 0.5]  # x·w1 column 0 = 0 exactly
        got = native_forward(w1, b1, w2, b2, xs, rows, d, h, c)
        for g in range(rows):
            want = reference_forward(w1, b1, w2, b2, xs[g * d : (g + 1) * d], d, h, c)
            self.assertEqual(got[g * c : (g + 1) * c], want)
            self.assertEqual(want, [0.125])  # both units dead → bias only


if __name__ == "__main__":
    unittest.main()
