"""Pallas kernel vs pure-jnp reference — the core L1 correctness signal.

The kernel implements the paper's select-based (mux) algorithm; ref.py the
sequential (LZC+shift) one. Bit-exact agreement across shapes and dtypes is
the software analogue of the paper's RTL equivalence between the b-posit
and standard-posit datapaths.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bposit, ref


@given(bits=st.lists(st.integers(min_value=0, max_value=0xFFFFFFFF), min_size=1, max_size=300))
@settings(max_examples=100, deadline=None)
def test_decode_kernel_matches_ref(bits):
    arr = jnp.asarray(np.array(bits, dtype=np.uint64).astype(np.uint32).view(np.int32))
    a = np.array(ref.decode_ref(arr))
    b = np.array(bposit.decode(arr))
    nan = np.isnan(a) & np.isnan(b)
    assert np.array_equal(a[~nan], b[~nan])


@given(
    xs=st.lists(
        st.floats(allow_nan=True, allow_infinity=True, width=32),
        min_size=1,
        max_size=300,
    )
)
@settings(max_examples=100, deadline=None)
def test_encode_kernel_matches_ref(xs):
    arr = jnp.asarray(np.array(xs, dtype=np.float32))
    a = np.array(ref.encode_ref(arr))
    b = np.array(bposit.encode(arr))
    assert np.array_equal(a, b)


@pytest.mark.parametrize("shape", [(8, 8), (64, 64), (64, 128), (128, 16), (32, 256)])
def test_matmul_kernel_matches_ref_shapes(shape):
    m, n = shape
    k = 64
    rng = np.random.RandomState(m * 1000 + n)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    wb = ref.encode_ref(jnp.asarray(rng.randn(k * n).astype(np.float32) * 0.5)).reshape(k, n)
    a = np.array(ref.matmul_ref(x, wb))
    b = np.array(bposit.matmul(x, wb, bm=min(m, 32), bn=min(n, 64)))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block", [64, 512, 4096])
def test_codec_block_sizes(block):
    rng = np.random.RandomState(block)
    xs = jnp.asarray(rng.randn(4096).astype(np.float32) * 100)
    enc = bposit.encode(xs, block=block)
    assert np.array_equal(np.array(enc), np.array(ref.encode_ref(xs)))
    dec = bposit.decode(enc, block=block)
    assert np.array_equal(np.array(dec), np.array(ref.decode_ref(enc)))


def test_roundtrip_through_kernels_fovea_exact():
    rng = np.random.RandomState(7)
    xs = jnp.asarray((rng.randn(2048) * 50).astype(np.float32))
    back = np.array(bposit.decode(bposit.encode(xs)))
    assert np.array_equal(back, np.array(xs))


def test_grid_tiling_consistency():
    # Same data through different grids must produce identical bits.
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(128, 64).astype(np.float32))
    wb = bposit.encode(jnp.asarray(rng.randn(64 * 128).astype(np.float32))).reshape(64, 128)
    a = np.array(bposit.matmul(x, wb, bm=128, bn=128))
    b = np.array(bposit.matmul(x, wb, bm=32, bn=32))
    np.testing.assert_array_equal(a, b)
