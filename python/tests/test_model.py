"""L2 model tests: shapes, training signal, quantization quality, and the
AOT lowering path."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.aot import lower_codec, lower_model_bposit, lower_model_f32, to_hlo_text


def test_forward_shapes():
    params = model.init_params(0)
    x = jnp.zeros((model.BATCH, model.D), jnp.float32)
    logits = model.forward_f32(params, x)
    assert logits.shape == (model.BATCH, model.C)
    w1b, w2b = model.quantize_params(params)
    assert w1b.shape == (model.D, model.H) and w1b.dtype == jnp.int32
    q = model.forward_bposit(x, w1b, params["b1"], w2b, params["b2"])
    assert q.shape == (model.BATCH, model.C)


def test_training_reduces_loss():
    _, history, acc = model.train(steps=60)
    assert history[0][1] > history[-1][1], f"loss did not drop: {history}"
    assert acc > 0.8


def test_quantized_forward_matches_oracle():
    params = model.init_params(1)
    x, _ = model.make_dataset(seed=3, per_class=4)
    x = x[: model.BATCH]
    w1b, w2b = model.quantize_params(params)
    got = model.forward_bposit(x, w1b, params["b1"], w2b, params["b2"])
    want = model._ref_forward_bposit(x, w1b, params["b1"], w2b, params["b2"])
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-6, atol=1e-6)


def test_quantization_error_small():
    # b-posit32 carries ≥ f32 precision across the weight range: the
    # quantized logits stay within float-rounding distance of f32 logits.
    params, _, _ = model.train(steps=40)
    x, y = model.make_dataset(seed=2, per_class=8)
    x = x[: model.BATCH]
    w1b, w2b = model.quantize_params(params)
    q = model.forward_bposit(x, w1b, params["b1"], w2b, params["b2"])
    f = model.forward_f32(params, x)
    rel = np.abs(np.array(q) - np.array(f)) / (np.abs(np.array(f)) + 1e-3)
    assert rel.max() < 1e-4, f"quantized drift too large: {rel.max()}"


def test_hlo_lowering_produces_text():
    for lowered in [lower_model_f32(), lower_model_bposit()]:
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ROOT" in text
    dec, enc = lower_codec()
    assert to_hlo_text(dec).startswith("HloModule")
    assert to_hlo_text(enc).startswith("HloModule")
