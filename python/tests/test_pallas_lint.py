"""pallas-lint enforcement tests (pure stdlib, always collected).

Three layers: the fixture corpus (`--self-test`, one must-fire and one
must-not-fire file per rule plus suppression-syntax cases), a clean run
over the real tree (the repo must stay violation-free — this is the same
gate the CI lint job runs), and an injection round-trip proving the lint
actually *fails* when a must-fire snippet lands in a zoned module.
"""

import importlib.util
import json
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[2]
TOOL = REPO / "tools" / "pallas_lint.py"
MANIFEST = REPO / "tools" / "lint_manifest.json"
FIXTURES = REPO / "tools" / "lint_fixtures"


def _load_tool():
    spec = importlib.util.spec_from_file_location("pallas_lint", TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fixture_corpus_self_test():
    proc = subprocess.run(
        [sys.executable, str(TOOL), "--self-test"], capture_output=True, text=True
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_tree_is_violation_free():
    proc = subprocess.run([sys.executable, str(TOOL)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_manifest_names_only_known_rules_and_real_paths():
    lint = _load_tool()
    manifest = json.loads(MANIFEST.read_text())
    for zone in manifest["zones"]:
        for rule in zone["rules"]:
            assert rule in lint.RULES, f"zone {zone['name']} names unknown rule {rule}"
        for path in zone["paths"]:
            assert (REPO / path).exists(), f"zone {zone['name']} maps missing path {path}"
    for path in manifest.get("ordering_allowed", []):
        assert (REPO / path).exists(), f"ordering_allowed maps missing path {path}"


def test_every_rule_has_fire_and_clean_fixture_coverage():
    lint = _load_tool()
    pragma = re.compile(r"lint-fixture:\s*zone=(\w+)\s*expect=([\w\-:,@]*)")
    fired, clean_zones = set(), set()
    for fx in sorted(FIXTURES.glob("*.rs")):
        m = pragma.search(fx.read_text())
        assert m, f"{fx.name} missing pragma"
        expect = [p.partition("@")[0] for p in filter(None, m.group(2).split(","))]
        if expect:
            fired.update(expect)
        else:
            clean_zones.add(m.group(1))
    assert fired == set(lint.RULES), (
        f"rules without a must-fire fixture: {set(lint.RULES) - fired}"
    )
    # Every zone has at least one must-not-fire fixture proving the rules
    # don't fire on idiomatic code.
    assert {"serving", "kernel", "default"} <= clean_zones


def test_injected_violation_fails_the_tree_lint():
    """End-to-end: drop a must-fire snippet into a serving-zone module and
    the tree lint must exit non-zero naming that file and rule."""
    lint = _load_tool()
    manifest = json.loads(MANIFEST.read_text())
    target = REPO / "rust" / "src" / "json.rs"
    original = target.read_text()
    injected = original + "\nfn injected_by_test(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n"
    try:
        target.write_text(injected)
        violations = lint.lint_tree(REPO, manifest)
    finally:
        target.write_text(original)
    hits = [v for v in violations if v.rule == "no-panic" and "json.rs" in v.rel]
    assert hits, f"injected unwrap not caught; got {[str(v) for v in violations]}"


def test_suppression_requires_matching_rule_name():
    """A lint:allow naming the wrong rule must not mask a violation."""
    lint = _load_tool()
    src = (
        "fn f(buf: &[u8]) -> u8 {\n"
        "    buf[0] // lint:allow(no-panic): wrong rule\n"
        "}\n"
    )
    manifest = json.loads(MANIFEST.read_text())
    got = {v.rule for v in lint.lint_file("x.rs", src, ["no-indexing"], manifest)}
    assert got == {"no-indexing"}
