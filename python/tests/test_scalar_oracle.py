"""Pure-stdlib tests of the big-int scalar oracle (compile/kernels/
scalar.py) — the cross-language ground truth the Rust codecs (general,
scalar-fast, and vector lane) are all verified against. No jax/numpy
needed, so these run everywhere, including the bare-interpreter CI job."""

import math

from compile.kernels import scalar


def test_roundtrip_all_p16():
    spec = scalar.P16
    for bits in range(1 << 16):
        v = scalar.decode(spec, bits)
        if v is None:
            assert bits == spec.nar
            continue
        assert scalar.encode(spec, v) == bits, hex(bits)


def test_roundtrip_all_bp16():
    spec = scalar.BP16
    for bits in range(1 << 16):
        v = scalar.decode(spec, bits)
        if v is None:
            continue
        assert scalar.encode(spec, v) == bits, hex(bits)


def test_bp32_known_patterns():
    spec = scalar.BP32
    assert scalar.encode(spec, 1.0) == 0x40000000
    assert scalar.encode(spec, -1.0) == 0xC0000000
    assert scalar.decode(spec, 0x40000000) == 1
    assert scalar.decode(spec, 0) == 0
    assert scalar.decode(spec, spec.nar) is None
    assert scalar.encode(spec, float("nan")) == spec.nar
    assert scalar.encode(spec, float("inf")) == spec.nar


def test_bp32_dynamic_range():
    spec = scalar.BP32
    # minpos scale 2^-192·(1+2^-20); maxpos just under 2^192.
    minpos = scalar.decode(spec, 1)
    assert math.isclose(float(minpos), 2.0**-192, rel_tol=1e-5)
    maxpos = scalar.decode(spec, spec.maxpos_body)
    assert 2.0**191 <= float(maxpos) < 2.0**192


def test_saturation_never_nar():
    for spec in (scalar.P16, scalar.BP16, scalar.BP32, scalar.P32):
        assert scalar.encode(spec, 1e300) == spec.maxpos_body
        assert scalar.encode(spec, -1e300) == (spec.nar + 1) & spec.mask
        assert scalar.encode(spec, 1e-300) == 1
        assert scalar.encode(spec, -1e-300) == spec.mask
