"""Pure-stdlib mirror of the `rust/src/certify/` interval subsystem.

The certify subsystem propagates directed-rounding intervals (efloat.nim's
lo/hi idiom: round every lower endpoint one float down, every upper
endpoint one float up) through the serving forward pass. This mirror
proves the recurrence against exact `Fraction` arithmetic BEFORE the Rust
transliteration, exactly like the codec/solver oracles:

- `next_f32/prev_f32/next_f64/prev_f64` mirror the planned
  `LaneElem::next_float/prev_float` bit manipulation verbatim;
- the interval ops (`iadd/isub/imul/imad/irelu`) mirror
  `certify::interval` op for op, including NaN poisoning and the
  explicit-compare (no float min/max — kernel lint zone) corner
  selection order in `imul`;
- the interval forward mirror follows `reference_forward`'s ascending-p
  accumulation chain, which the blocked GEMM is CI-gated bit-identical
  to — so an interval that contains every same-order fl() evaluation
  also contains the served logits.

Why containment holds (the induction the tests check):
  maintain that [lo,hi] contains BOTH the exact real value AND every
  round-to-nearest evaluation (in this op order) of the subexpression,
  for operands anywhere in the input intervals. RNE is monotone, so
  fl(a'∘b') ∈ [fl(lo∘lo), fl(hi∘hi)] ⊆ [prev(fl(..)), next(fl(..))];
  and prev(fl(z)) ≤ z ≤ next(fl(z)) for every real z, so the exact
  value stays inside too.

This file also GENERATES rust/tests/data/certify_golden.json (run it as
a script to regenerate); `test_committed_golden_file_is_current` keeps
the committed copy in sync, and the Rust side replays the op chains
bit-for-bit.
"""

import json
import math
import pathlib
import random
import struct
import sys
from fractions import Fraction

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from compile.kernels import scalar

REPO = pathlib.Path(__file__).resolve().parents[2]
GOLDEN_PATH = REPO / "rust" / "tests" / "data" / "certify_golden.json"

NAN = float("nan")
INF = float("inf")

# ----------------------------------------------------------------------
# f32 arithmetic on top of Python's f64.
#
# Sums/differences of two f32 values need ≤ 49 significant bits only when
# exponents are close; in general the f64 intermediate rounds — but by
# Figueroa's innocuous-double-rounding theorem (p2 ≥ 2·p1 + 2; 53 ≥ 50),
# rounding the f64 RNE result to f32 equals the directly-rounded f32 op
# for +, −, ×. Products of two f32 are always exact in f64 (≤ 48 bits).
# ----------------------------------------------------------------------


def f32(x: float) -> float:
    """Round an f64 to f32 under RNE (overflow → ±inf, like the C cast)."""
    try:
        return struct.unpack("<f", struct.pack("<f", x))[0]
    except OverflowError:
        return -INF if x < 0 else INF


def f32_bits(x: float) -> int:
    return struct.unpack("<I", struct.pack("<f", x))[0]


def bits_f32(b: int) -> float:
    return struct.unpack("<f", struct.pack("<I", b & 0xFFFFFFFF))[0]


f64_bits = scalar.f64_to_bits
bits_f64 = scalar.bits_to_f64


# ----------------------------------------------------------------------
# next/prev float — verbatim mirrors of LaneElem::{next_float,prev_float}
# (rust/src/vector/lane.rs). Both zeros step to the smallest subnormal of
# the opposite sign class, NaN and the unmovable infinity return
# themselves.
# ----------------------------------------------------------------------


def next_f32(x: float) -> float:
    if math.isnan(x) or x == INF:
        return x
    if x == 0.0:
        return bits_f32(1)
    b = f32_bits(x)
    return bits_f32(b + 1) if (b >> 31) == 0 else bits_f32(b - 1)


def prev_f32(x: float) -> float:
    if math.isnan(x) or x == -INF:
        return x
    if x == 0.0:
        return bits_f32(0x8000_0001)
    b = f32_bits(x)
    return bits_f32(b - 1) if (b >> 31) == 0 else bits_f32(b + 1)


def next_f64(x: float) -> float:
    if math.isnan(x) or x == INF:
        return x
    if x == 0.0:
        return bits_f64(1)
    b = f64_bits(x)
    return bits_f64(b + 1) if (b >> 63) == 0 else bits_f64(b - 1)


def prev_f64(x: float) -> float:
    if math.isnan(x) or x == -INF:
        return x
    if x == 0.0:
        return bits_f64(0x8000_0000_0000_0001)
    b = f64_bits(x)
    return bits_f64(b - 1) if (b >> 63) == 0 else bits_f64(b + 1)


class Mode:
    """One float width: rounding fn + directed neighbors + bit codecs."""

    def __init__(self, name, fl, nxt, prv, to_bits, from_bits):
        self.name = name
        self.fl = fl
        self.nxt = nxt
        self.prv = prv
        self.to_bits = to_bits
        self.from_bits = from_bits


M32 = Mode("f32", f32, next_f32, prev_f32, f32_bits, bits_f32)
M64 = Mode("f64", lambda x: x, next_f64, prev_f64, f64_bits, bits_f64)

# ----------------------------------------------------------------------
# Interval ops — the certify::interval mirror. An interval is a (lo, hi)
# tuple; the poisoned (NaN) interval is (nan, nan) and propagates.
# ----------------------------------------------------------------------

POISON = (NAN, NAN)


def poisoned(a) -> bool:
    return math.isnan(a[0]) or math.isnan(a[1])


def ipoint(m: Mode, v: float):
    if math.isnan(v):
        return POISON
    return (v, v)


def iadd(m: Mode, a, b):
    if poisoned(a) or poisoned(b):
        return POISON
    lo = m.fl(a[0] + b[0])
    hi = m.fl(a[1] + b[1])
    if math.isnan(lo) or math.isnan(hi):  # inf + -inf
        return POISON
    return (m.prv(lo), m.nxt(hi))


def isub(m: Mode, a, b):
    if poisoned(a) or poisoned(b):
        return POISON
    lo = m.fl(a[0] - b[1])
    hi = m.fl(a[1] - b[0])
    if math.isnan(lo) or math.isnan(hi):
        return POISON
    return (m.prv(lo), m.nxt(hi))


def imul(m: Mode, a, b):
    if poisoned(a) or poisoned(b):
        return POISON
    # Corner products in this fixed order; selection keeps the FIRST
    # extremum on ties (explicit `<` / `>` compares, mirroring the
    # lint-zone-safe Rust loop — no float min/max).
    c0 = m.fl(a[0] * b[0])
    c1 = m.fl(a[0] * b[1])
    c2 = m.fl(a[1] * b[0])
    c3 = m.fl(a[1] * b[1])
    if math.isnan(c0) or math.isnan(c1) or math.isnan(c2) or math.isnan(c3):
        return POISON  # 0 × inf
    lo = c0
    hi = c0
    for v in (c1, c2, c3):
        if v < lo:
            lo = v
        if v > hi:
            hi = v
    return (m.prv(lo), m.nxt(hi))


def imad(m: Mode, a, b, c):
    """mul_add as the mul-then-add composition (the kernel zone bans the
    fused fp mul_add; the interval op composes the two audited ops)."""
    return iadd(m, imul(m, a, b), c)


def irelu(m: Mode, a):
    if poisoned(a):
        return POISON
    lo = a[0] if a[0] > 0.0 else 0.0
    hi = a[1] if a[1] > 0.0 else 0.0
    return (lo, hi)


def ihull(m: Mode, x: float, y: float):
    if math.isnan(x) or math.isnan(y):
        return POISON
    return (x, y) if x < y else (y, x)


def iwidth(a) -> float:
    """Certified width as an f64 upper bound on hi − lo (one extra
    next_f64 absorbs the subtraction's own rounding). Poisoned → +inf
    (fail closed)."""
    if poisoned(a):
        return INF
    w = a[1] - a[0]
    if math.isnan(w) or math.isinf(w):
        return INF
    return next_f64(w)


def icontains(a, v: float) -> bool:
    return (not poisoned(a)) and (not math.isnan(v)) and a[0] <= v <= a[1]


# ----------------------------------------------------------------------
# Exact twin: the same expression DAG over exact Fraction endpoints.
# fp_interval must always contain exact_interval.
# ----------------------------------------------------------------------


def eadd(a, b):
    return (a[0] + b[0], a[1] + b[1])


def esub(a, b):
    return (a[0] - b[1], a[1] - b[0])


def emul(a, b):
    cs = (a[0] * b[0], a[0] * b[1], a[1] * b[0], a[1] * b[1])
    return (min(cs), max(cs))


def emad(a, b, c):
    return eadd(emul(a, b), c)


def erelu(a):
    z = Fraction(0)
    return (a[0] if a[0] > z else z, a[1] if a[1] > z else z)


def efrom(a):
    """Exact twin of an fp interval's endpoints."""
    return (Fraction(a[0]), Fraction(a[1]))


def fr_round_down(fr: Fraction) -> float:
    """Largest f64 ≤ fr (float(Fraction) is correctly RNE-rounded)."""
    f = float(fr)
    if math.isinf(f):
        return prev_f64(f) if f > 0 and Fraction(prev_f64(f)) >= fr else f
    return prev_f64(f) if Fraction(f) > fr else f


def fr_round_up(fr: Fraction) -> float:
    """Smallest f64 ≥ fr."""
    f = float(fr)
    if math.isinf(f):
        return next_f64(f) if f < 0 and Fraction(next_f64(f)) <= fr else f
    return next_f64(f) if Fraction(f) < fr else f


def contains_exact(a, e) -> bool:
    """fp interval ⊇ exact interval (endpoint comparison through the
    directed f64 brackets — sound and slack-free, since every fp
    endpoint is itself an f64)."""
    if poisoned(a):
        return False
    return a[0] <= fr_round_down(e[0]) and fr_round_up(e[1]) <= a[1]


# ----------------------------------------------------------------------
# Spec-flavored quantization (input intervals for the op chains).
# ----------------------------------------------------------------------

SPECS = {
    "BP16": (scalar.BP16, M32),
    "BP32": (scalar.BP32, M32),
    "P32": (scalar.P32, M32),
    "BP64": (scalar.BP64, M64),
    "P64": (scalar.P64, M64),
}


def quantize(spec, m: Mode, v: float) -> float:
    """Lane roundtrip of v under spec, narrowed to the mode width (the
    narrowing is a single rounding: every ≤32-bit spec's fraction is
    exact in f64)."""
    q = scalar.decode_f64_contract(spec, scalar.encode_f64_contract(spec, v))
    q = m.fl(q)
    # The f32 lane contract flushes below the f32 normal range.
    if m is M32 and q != 0.0 and abs(q) < 2.0**-126:
        return -0.0 if q < 0 else 0.0
    return q


# ----------------------------------------------------------------------
# Forward-pass mirrors (the bp32 serving tier): reference_forward's
# ascending-p chains, in fp / interval / exact flavors. Weight layout is
# transposed (wt1[i*d+p] = dequantized w1[p*h+i]) to match the certify
# state the Rust side decodes from its EncodedTensors.
# ----------------------------------------------------------------------


def ref_forward32(w1t, b1, w2t, b2, x, d, h, c):
    hid = []
    for i in range(h):
        acc = 0.0
        for p in range(d):
            acc = f32(acc + f32(w1t[i * d + p] * x[p]))
        v = f32(acc + b1[i])
        hid.append(v if v > 0.0 else 0.0)
    out = []
    for q in range(c):
        acc = 0.0
        for i in range(h):
            acc = f32(acc + f32(w2t[q * h + i] * hid[i]))
        out.append(f32(acc + b2[q]))
    return out


def interval_forward(m, w1t, b1, w2t, b2, xints, d, h, c):
    hid = []
    for i in range(h):
        acc = (0.0, 0.0)
        for p in range(d):
            acc = iadd(m, acc, imul(m, ipoint(m, w1t[i * d + p]), xints[p]))
        hid.append(irelu(m, iadd(m, acc, ipoint(m, b1[i]))))
    out = []
    for q in range(c):
        acc = (0.0, 0.0)
        for i in range(h):
            acc = iadd(m, acc, imul(m, ipoint(m, w2t[q * h + i]), hid[i]))
        out.append(iadd(m, acc, ipoint(m, b2[q])))
    return out


def exact_forward(w1t, b1, w2t, b2, xints, d, h, c):
    """Exact interval twin over Fractions (the ground truth the fp
    intervals must contain)."""
    hid = []
    for i in range(h):
        acc = (Fraction(0), Fraction(0))
        for p in range(d):
            wp = Fraction(w1t[i * d + p])
            acc = eadd(acc, emul((wp, wp), xints[p]))
        bi = Fraction(b1[i])
        hid.append(erelu(eadd(acc, (bi, bi))))
    out = []
    for q in range(c):
        acc = (Fraction(0), Fraction(0))
        for i in range(h):
            wq = Fraction(w2t[q * h + i])
            acc = eadd(acc, emul((wq, wq), hid[i]))
        bq = Fraction(b2[q])
        out.append(eadd(acc, (bq, bq)))
    return out


def exact_point_forward(w1t, b1, w2t, b2, x, d, h, c):
    """Exact real-arithmetic forward at a point input (the value the
    certified bound must cover)."""
    xi = [(Fraction(v), Fraction(v)) for v in x]
    return [e[0] for e in exact_forward(w1t, b1, w2t, b2, xi, d, h, c)]


# ----------------------------------------------------------------------
# Unit tests: neighbors, op semantics, poisoning.
# ----------------------------------------------------------------------


def test_next_prev_float_edges():
    assert next_f32(0.0) == bits_f32(1) and next_f32(-0.0) == bits_f32(1)
    assert prev_f32(0.0) == bits_f32(0x8000_0001)
    assert f32_bits(prev_f32(bits_f32(1))) == 0  # tiny → +0
    assert next_f32(-bits_f32(1)) == 0.0
    assert prev_f32(INF) == bits_f32(0x7F7F_FFFF)  # +MAX
    assert next_f32(-INF) == bits_f32(0xFF7F_FFFF)  # −MAX
    assert next_f32(INF) == INF and prev_f32(-INF) == -INF
    assert math.isnan(next_f32(NAN)) and math.isnan(prev_f32(NAN))
    assert next_f32(bits_f32(0x7F7F_FFFF)) == INF
    assert prev_f64(INF) == bits_f64(0x7FEF_FFFF_FFFF_FFFF)
    assert next_f64(0.0) == bits_f64(1) and prev_f64(-0.0) == bits_f64(0x8000_0000_0000_0001)
    for m in (M32, M64):
        for v in (1.0, -1.0, 0.5, -2.75, 1e-20, -3e10):
            v = m.fl(v)
            assert m.prv(v) < v < m.nxt(v)
            assert m.nxt(m.prv(v)) == v and m.prv(m.nxt(v)) == v


def test_directed_neighbors_bracket_every_real():
    # prev(fl(z)) ≤ z ≤ next(fl(z)) — the keystone of the containment
    # induction, checked on exact rationals that round both ways.
    rng = random.Random(0xCE47)
    for m in (M32, M64):
        for _ in range(500):
            z = Fraction(rng.getrandbits(40) - (1 << 39), rng.getrandbits(20) + 1)
            fl = m.fl(float(z))  # float(Fraction) RNE + mode narrowing
            assert Fraction(m.prv(fl)) <= z <= Fraction(m.nxt(fl))


def test_interval_ops_contain_exact_and_fl_results():
    rng = random.Random(0x1A7E)
    for m in (M32, M64):
        for _ in range(300):
            mk = lambda: m.fl(rng.uniform(-6, 6))
            a = ihull(m, mk(), mk())
            b = ihull(m, mk(), mk())
            for op, eop in ((iadd, eadd), (isub, esub), (imul, emul)):
                r = op(m, a, b)
                assert contains_exact(r, eop(efrom(a), efrom(b)))
                # fl() evaluations at sampled operand points stay inside.
                for _ in range(4):
                    av = m.fl(rng.uniform(a[0], a[1]))
                    bv = m.fl(rng.uniform(b[0], b[1]))
                    av = min(max(av, a[0]), a[1])
                    bv = min(max(bv, b[0]), b[1])
                    if op is iadd:
                        v = m.fl(av + bv)
                    elif op is isub:
                        v = m.fl(av - bv)
                    else:
                        v = m.fl(av * bv)
                    assert icontains(r, v), (m.name, op.__name__, a, b, v, r)
            c = ihull(m, mk(), mk())
            r = imad(m, a, b, c)
            assert contains_exact(r, emad(efrom(a), efrom(b), efrom(c)))
            r = irelu(m, a)
            assert contains_exact(r, erelu(efrom(a)))


def test_nan_poisoning_and_infinities():
    for m in (M32, M64):
        assert poisoned(iadd(m, POISON, (1.0, 2.0)))
        assert poisoned(imul(m, (1.0, 2.0), POISON))
        assert poisoned(irelu(m, POISON))
        assert poisoned(ipoint(m, NAN))
        # 0 × inf poisons; inf − inf poisons.
        assert poisoned(imul(m, (0.0, 0.0), (INF, INF)))
        assert poisoned(isub(m, (INF, INF), (INF, INF)))
        # Plain overflow widens to inf but stays ordered, not poisoned.
        big = m.fl(3.0e38) if m is M32 else 1.0e308
        r = imul(m, (big, big), (big, big))
        assert not poisoned(r) and r[1] == INF
        assert iwidth(r) == INF and iwidth(POISON) == INF
    assert iwidth((1.0, 1.0)) >= 0.0
    assert not icontains(POISON, 1.0) and not icontains((0.0, 1.0), NAN)


def test_width_upper_bounds_endpoint_gap():
    rng = random.Random(0xD1F)
    for m in (M32, M64):
        for _ in range(200):
            a = ihull(m, m.fl(rng.uniform(-1e3, 1e3)), m.fl(rng.uniform(-1e-3, 1e9)))
            w = iwidth(a)
            assert Fraction(w) >= Fraction(a[1]) - Fraction(a[0])


# ----------------------------------------------------------------------
# Random op-chain property + golden generation (satellite: proptest
# across {BP16, BP32, P32, BP64, P64}).
# ----------------------------------------------------------------------


def _gen_chain(spec_name: str, seed: int):
    spec, m = SPECS[spec_name]
    rng = random.Random(seed)
    n_inputs = 5
    inputs = []
    for _ in range(n_inputs):
        v = m.fl(rng.uniform(-4.0, 4.0))
        q = quantize(spec, m, v)
        inputs.append(ihull(m, v, q))
    ops = []
    for _ in range(10):
        kind = rng.choice(["add", "sub", "mul", "mad", "relu"])
        if kind == "relu":
            ops.append(["relu"])
        elif kind == "mad":
            ops.append(["mad", rng.randrange(n_inputs), rng.randrange(n_inputs)])
        else:
            ops.append([kind, rng.randrange(n_inputs)])
    return inputs, ops


def _run_chain(m: Mode, inputs, ops):
    acc = inputs[0]
    eacc = efrom(inputs[0])
    eins = [efrom(i) for i in inputs]
    for op in ops:
        if op[0] == "add":
            acc = iadd(m, acc, inputs[op[1]])
            eacc = eadd(eacc, eins[op[1]])
        elif op[0] == "sub":
            acc = isub(m, acc, inputs[op[1]])
            eacc = esub(eacc, eins[op[1]])
        elif op[0] == "mul":
            acc = imul(m, acc, inputs[op[1]])
            eacc = emul(eacc, eins[op[1]])
        elif op[0] == "mad":
            acc = imad(m, acc, inputs[op[1]], inputs[op[2]])
            eacc = emad(eacc, eins[op[1]], eins[op[2]])
        elif op[0] == "relu":
            acc = irelu(m, acc)
            eacc = erelu(eacc)
        else:  # pragma: no cover
            raise AssertionError(op)
    return acc, eacc


def test_random_op_chains_contain_exact_across_specs():
    for spec_name in SPECS:
        _, m = SPECS[spec_name]
        for seed in range(12):
            inputs, ops = _gen_chain(spec_name, (hash(spec_name) & 0xFFFF) * 64 + seed)
            acc, eacc = _run_chain(m, inputs, ops)
            assert not poisoned(acc), (spec_name, seed)
            assert contains_exact(acc, eacc), (spec_name, seed, acc, eacc)
            assert math.isfinite(iwidth(acc)), (spec_name, seed)


def _hex(m: Mode, v: float) -> str:
    return f"{m.to_bits(v):0{16 if m is M64 else 8}x}"


def _build_golden():
    chains = []
    for spec_name in sorted(SPECS):
        spec, m = SPECS[spec_name]
        for seed in range(4):
            inputs, ops = _gen_chain(spec_name, 0x60 + seed * 7 + len(spec_name))
            acc, eacc = _run_chain(m, inputs, ops)
            assert not poisoned(acc) and math.isfinite(iwidth(acc))
            assert contains_exact(acc, eacc)
            chains.append(
                {
                    "spec": spec_name,
                    "mode": m.name,
                    "inputs": [[_hex(m, lo), _hex(m, hi)] for lo, hi in inputs],
                    "ops": ops,
                    "final": [_hex(m, acc[0]), _hex(m, acc[1])],
                    "exact_lo": f"{f64_bits(fr_round_down(eacc[0])):016x}",
                    "exact_hi": f"{f64_bits(fr_round_up(eacc[1])):016x}",
                }
            )
    return {
        "generator": "python/tests/test_certify_mirror.py",
        "semantics": "acc=inputs[0]; add/sub/mul j: acc∘inputs[j]; "
        "mad j k: acc*inputs[j]+inputs[k]; relu. Bits are hex of the "
        "mode width; exact_lo/exact_hi bracket the exact interval "
        "(f64 rounded towards it).",
        "chains": chains,
    }


def _golden_text() -> str:
    return json.dumps(_build_golden(), indent=1, sort_keys=True) + "\n"


def test_committed_golden_file_is_current():
    assert GOLDEN_PATH.is_file(), (
        f"{GOLDEN_PATH} missing — regenerate with "
        "`python3 python/tests/test_certify_mirror.py`"
    )
    assert GOLDEN_PATH.read_text(encoding="utf-8") == _golden_text(), (
        "committed certify goldens drifted from the mirror — regenerate "
        "with `python3 python/tests/test_certify_mirror.py`"
    )


# ----------------------------------------------------------------------
# Forward-pass containment on a synthetic model (the certify-bench
# dress rehearsal: tunes the width-vs-error gate constants).
# ----------------------------------------------------------------------


def _synth_model(rng, d, h, c):
    spec = scalar.BP32
    w1t = [0.0] * (d * h)
    w2t = [0.0] * (h * c)
    for i in range(h):
        for p in range(d):
            w1t[i * d + p] = quantize(spec, M32, f32((rng.random() - 0.5) * 0.5))
    for q in range(c):
        for i in range(h):
            w2t[q * h + i] = quantize(spec, M32, f32((rng.random() - 0.5) * 0.5))
    b1 = [f32((rng.random() - 0.5) * 0.2) for _ in range(h)]
    b2 = [f32((rng.random() - 0.5) * 0.2) for _ in range(c)]
    return w1t, b1, w2t, b2


def test_bp32_forward_bounds_contain_reference_and_exact():
    d, h, c = 16, 12, 6
    rng = random.Random(0xF0A4)
    w1t, b1, w2t, b2 = _synth_model(rng, d, h, c)
    spec = scalar.BP32
    max_ratio = 0.0
    for _ in range(6):
        x_raw = [f32(rng.uniform(-1.0, 1.0)) for _ in range(d)]  # off-grid
        x_q = [quantize(spec, M32, v) for v in x_raw]
        xints = [ihull(M32, x_raw[p], x_q[p]) for p in range(d)]

        bounds = interval_forward(M32, w1t, b1, w2t, b2, xints, d, h, c)
        served = ref_forward32(w1t, b1, w2t, b2, x_q, d, h, c)
        ref_raw = ref_forward32(w1t, b1, w2t, b2, x_raw, d, h, c)
        exact = exact_point_forward(w1t, b1, w2t, b2, x_raw, d, h, c)
        eints = exact_forward(
            w1t, b1, w2t, b2, [(Fraction(a), Fraction(b)) for a, b in xints], d, h, c
        )

        widths = [iwidth(bv) for bv in bounds]
        errs = [abs(Fraction(served[j]) - exact[j]) for j in range(c)]
        for j in range(c):
            assert icontains(bounds[j], served[j]), j
            assert icontains(bounds[j], ref_raw[j]), j
            assert contains_exact(bounds[j], eints[j]), j
            assert Fraction(widths[j]) >= errs[j], j  # bound really bounds
        max_w = max(widths)
        max_e = max(errs)
        assert max_e > 0, "off-grid inputs must see real quantization error"
        assert math.isfinite(max_w) and max_w > 0.0
        max_ratio = max(max_ratio, max_w / float(max_e))
    # On a generic sign-mixed model the observed error random-walks
    # (~sqrt(n) cancellation per layer) while the certified width sums
    # contributions absolutely, so the width/error ratio here is large
    # (tens to low hundreds) — that is expected, not looseness the bench
    # gates on.  The width-vs-error CI gate runs on the coherent-rounding
    # probe model below (test_bench_probe_* ), where cancellation is
    # designed out and the ratio must clear 10x with margin.
    assert max_ratio < 1000.0, max_ratio


def test_bp64_forward_bounds_contain_f32_readout():
    # The 64-bit tier: f32-sourced weights encode losslessly in BP64 and
    # the inputs stage exactly, so the interval runs in f64 with point
    # inputs and the bound collapses to accumulated directed rounding —
    # then narrows outward through the f32 readout.
    d, h, c = 16, 12, 6
    rng = random.Random(0xB64)
    w1t, b1, w2t, b2 = _synth_model(rng, d, h, c)
    for _ in range(4):
        x = [f32(rng.uniform(-1.0, 1.0)) for _ in range(d)]
        xints = [ipoint(M64, v) for v in x]
        bounds = interval_forward(M64, w1t, b1, w2t, b2, xints, d, h, c)
        # f64 reference mirror (ascending-p, like reference_forward Bp64).
        hid = []
        for i in range(h):
            acc = 0.0
            for p in range(d):
                acc += w1t[i * d + p] * x[p]
            v = acc + b1[i]
            hid.append(v if v > 0.0 else 0.0)
        exact = exact_point_forward(w1t, b1, w2t, b2, x, d, h, c)
        for q in range(c):
            acc = 0.0
            for i in range(h):
                acc += w2t[q * h + i] * hid[i]
            logit64 = acc + b2[q]
            logit32 = f32(logit64)
            lo, hi = bounds[q]
            assert icontains((lo, hi), logit64)
            assert contains_exact((lo, hi), (exact[q], exact[q]))
            # Outward narrowing through the f32 readout keeps containment.
            lo32, hi32 = prev_f32(f32(lo)), next_f32(f32(hi))
            assert lo32 <= logit32 <= hi32
            assert Fraction(lo32) <= exact[q] <= Fraction(hi32)
            w = iwidth((float(lo32), float(hi32)))
            assert math.isfinite(w) and w < 1e-4  # a few f32 ulps


# ----------------------------------------------------------------------
# certify-bench probe mirror.  `cli certify-bench` transliterates exactly
# this: a SplitMix64 stream (mirror of rust/src/testutil Rng), a tiny
# positive-weight model at f32 exponent t=100 (inside BP32's rounding
# band, where b-posit(32,6,5) keeps only 21 fraction bits), and inputs
# built as an 18-bit-fraction BP32 grid point plus a sub-half-ulp offset
# so every quantization rounds DOWN.  Coherent rounding + positive
# weights = no error cancellation, so the observed quantization error
# tracks the certified width and the <10x CI tightness gate has real
# margin.  The pinned hex constants below are the exact f64 bits the
# Rust bench must reproduce (it is a transliteration, so bit-equality is
# the correctness test).
# ----------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


class SplitMix:
    """Mirror of rust/src/testutil/mod.rs `Rng` (SplitMix64)."""

    def __init__(self, seed: int):
        self.s = (seed + 0x9E3779B97F4A7C15) & _MASK64

    def next_u64(self) -> int:
        self.s = (self.s + 0x9E3779B97F4A7C15) & _MASK64
        z = self.s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def f64(self) -> float:
        return (self.next_u64() >> 11) / float(1 << 53)


BENCH_SEED = 5
BENCH_T = 100  # f32 exponent: BP32 fraction is 21 bits for t in [96,127]
BENCH_D, BENCH_H, BENCH_C = 4, 4, 3
BENCH_REQS = 64


def bench_model32(rng: SplitMix):
    """Positive-weight probe model; draw order is the Rust bench's."""
    scale = 2.0**BENCH_T
    w1t = [f32(0.3 + 0.7 * rng.f64()) for _ in range(BENCH_D * BENCH_H)]
    b1 = [f32(rng.f64() * 0.05 * scale) for _ in range(BENCH_H)]
    w2t = [f32(0.3 + 0.7 * rng.f64()) for _ in range(BENCH_H * BENCH_C)]
    b2 = [f32(rng.f64() * 0.05 * scale) for _ in range(BENCH_C)]
    return w1t, b1, w2t, b2


def bench_input32(rng: SplitMix) -> float:
    # 18-bit-fraction grid point (exact in BP32's 21-bit band) plus an
    # offset in [0.40, 0.45] of the BP32 ulp 2^(t-21): below the RNE
    # half-step, so quantization always rounds DOWN to the grid point.
    g = f32((1.0 + rng.below(1 << 18) * 2.0**-18) * 2.0**BENCH_T)
    off = f32((0.40 + 0.05 * rng.f64()) * 2.0 ** (BENCH_T - 21))
    return f32(g + off)


def ref_forward64(w1t, b1, w2t, b2, x, d, h, c):
    """f64 reference chain (ascending-p; mirror of reference_forward64)."""
    hid = []
    for i in range(h):
        acc = 0.0
        for p in range(d):
            acc += w1t[i * d + p] * x[p]
        v = acc + b1[i]
        hid.append(v if v > 0.0 else 0.0)
    out = []
    for q in range(c):
        acc = 0.0
        for i in range(h):
            acc += w2t[q * h + i] * hid[i]
        out.append(acc + b2[q])
    return out


def bench_probe32(spec):
    """One 32-bit-tier probe run: (max_width, max_obs_err, containment)."""
    d, h, c = BENCH_D, BENCH_H, BENCH_C
    rng = SplitMix(BENCH_SEED)
    w1t, b1, w2t, b2 = bench_model32(rng)
    max_w = 0.0
    max_e = 0.0
    contained = True
    for _ in range(BENCH_REQS):
        x_raw = [bench_input32(rng) for _ in range(d)]
        x_q = [quantize(spec, M32, v) for v in x_raw]
        xints = [ihull(M32, x_raw[p], x_q[p]) for p in range(d)]
        bounds = interval_forward(M32, w1t, b1, w2t, b2, xints, d, h, c)
        served = ref_forward32(w1t, b1, w2t, b2, x_q, d, h, c)
        ref = ref_forward64(w1t, b1, w2t, b2, x_raw, d, h, c)
        for j in range(c):
            if not (icontains(bounds[j], served[j]) and icontains(bounds[j], ref[j])):
                contained = False
            w = iwidth(bounds[j])
            e = abs(served[j] - ref[j])
            if w > max_w:
                max_w = w
            if e > max_e:
                max_e = e
    return max_w, max_e, contained


def bench_probe64():
    """BP64 probe: quantization of normal f64 is exact (PR 3), so the
    hull is a point and the certified width is pure directed-rounding
    accumulation — gated absolutely, not relative to observed error."""
    d, h, c = 16, 12, 6
    rng = SplitMix(BENCH_SEED)
    w1t = [f32(rng.f64() - 0.5) for _ in range(d * h)]
    b1 = [f32((rng.f64() - 0.5) * 0.2) for _ in range(h)]
    w2t = [f32(rng.f64() - 0.5) for _ in range(h * c)]
    b2 = [f32((rng.f64() - 0.5) * 0.2) for _ in range(c)]
    spec = scalar.BP64
    max_w = 0.0
    contained = True
    for _ in range(32):
        x = [(rng.f64() - 0.5) * 8.0 for _ in range(d)]
        x_q = [quantize(spec, M64, v) for v in x]
        assert x == x_q, "BP64 must encode normal f64 exactly"
        xints = [ipoint(M64, v) for v in x]
        bounds = interval_forward(M64, w1t, b1, w2t, b2, xints, d, h, c)
        served = ref_forward64(w1t, b1, w2t, b2, x, d, h, c)
        for j in range(c):
            if not icontains(bounds[j], served[j]):
                contained = False
            w = iwidth(bounds[j])
            if w > max_w:
                max_w = w
    return max_w, contained


# Exact f64 bits of (max_width, max_obs_err) the probes above produce —
# the Rust certify-bench must reproduce these bit-for-bit (CI compares
# the hex it emits in BENCH_certify.json against these constants).
BENCH_EXPECT = {
    "bp32": (0x4537000000000001, 0x451019777F000000),  # ratio 5.7145
    "p32": (0x462734AC00000001, 0x462473A1E1CAB670),  # ratio 1.1347
    "bp64": (0x3D30C00000000001,),  # width 5.951e-14
}


def test_bench_probe_bp32_ratio_under_gate():
    max_w, max_e, contained = bench_probe32(scalar.BP32)
    assert contained
    assert max_e > 0.0
    ratio = max_w / max_e
    # CI gates certify-bench at ratio < 10; the mirror pins the exact
    # value (~5.71) so the Rust transliteration is checkable bit-for-bit.
    assert ratio < 10.0, ratio
    assert f64_bits(max_w) == BENCH_EXPECT["bp32"][0], hex(f64_bits(max_w))
    assert f64_bits(max_e) == BENCH_EXPECT["bp32"][1], hex(f64_bits(max_e))


def test_bench_probe_p32_ratio_under_gate():
    # P32 (32,31,2) at t=100 carries a ~26-bit regime, leaving ~3
    # fraction bits: quantization error dominates the width, so the
    # bound is near-tight (~1.1x).
    max_w, max_e, contained = bench_probe32(scalar.P32)
    assert contained
    assert max_e > 0.0
    assert max_w / max_e < 10.0, max_w / max_e
    assert f64_bits(max_w) == BENCH_EXPECT["p32"][0], hex(f64_bits(max_w))
    assert f64_bits(max_e) == BENCH_EXPECT["p32"][1], hex(f64_bits(max_e))


def test_bench_probe_bp64_width_absolute():
    max_w, contained = bench_probe64()
    assert contained
    assert 0.0 < max_w < 1e-9, max_w
    assert f64_bits(max_w) == BENCH_EXPECT["bp64"][0], hex(f64_bits(max_w))


if __name__ == "__main__":
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(_golden_text(), encoding="utf-8")
    print(f"wrote {GOLDEN_PATH}")
