"""Pure-stdlib mirror of the Rust solver layer (rust/src/solver/ +
rust/src/vector/sparse.rs).

Like every bit-level layer before it (see test_scalar_oracle*.py), the
algorithm is proven here first and the Rust is a careful transliteration:

- the *chunk-aware* CSR fast SpMV row kernel is shown bitwise-identical to
  the dense 8-accumulator ``dot`` on densified matrices, at both widths
  (f64 native; f32 emulated with single-rounding via struct.pack);
- the tiered CG solver (fast / quire-exact reductions x f32 / f64) is run
  on the small exactly-representable Poisson operator to produce golden
  residual trajectories, embedded both here and in rust/tests/solver.rs —
  the cross-language contract is bitwise equality of every trajectory
  entry and of the final iterate;
- the CI bench gate's ordering claim (quire tier reaches tolerance in <=
  the f32 tier's iterations on the Poisson operator) is checked on the
  same operator set ``solver-bench --small`` runs;
- the Jacobi strict-win claim on the scale-skewed random diagonally-
  dominant operator is checked against the bitwise-mirrored constructor
  (SplitMix64 PRNG included).

Exact reductions use integer arithmetic over dyadic rationals (every f64
is m*2^e), with one correctly-rounded conversion at readout — CPython's
int/Fraction -> float conversion is round-to-nearest-even, the same
contract the Rust quire readout was validated against in earlier PRs.

Run as a script to (re)print the golden vectors embedded in the Rust test:

    python3 python/tests/test_solver_mirror.py --emit-goldens
"""

import math
import struct
from fractions import Fraction

# ----------------------------------------------------------------------
# Width emulation. Python floats are IEEE f64; f32 ops round each result
# through struct.pack (CPython packs via a native double->float cast,
# which is round-to-nearest-even, overflow -> OverflowError).
# ----------------------------------------------------------------------


def f32r(x):
    """Round an f64 to the nearest f32 (RNE), widened back to f64."""
    try:
        return struct.unpack("<f", struct.pack("<f", x))[0]
    except OverflowError:
        return math.inf if x > 0 else -math.inf


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


class F64Ops:
    """Native f64 arithmetic (one rounding per op, as in Rust)."""

    name = "f64"

    @staticmethod
    def rnd(x):
        return x

    @staticmethod
    def mul(a, b):
        return a * b

    @staticmethod
    def add(a, b):
        return a + b

    @staticmethod
    def sub(a, b):
        return a - b


class F32Ops:
    """Emulated f32 arithmetic: operands are f32-valued f64s, so the f64
    op is exact and one f32r gives the correctly-rounded f32 result."""

    name = "f32"

    @staticmethod
    def rnd(x):
        return f32r(x)

    @staticmethod
    def mul(a, b):
        return f32r(a * b)

    @staticmethod
    def add(a, b):
        return f32r(a + b)

    @staticmethod
    def sub(a, b):
        return f32r(a - b)


# ----------------------------------------------------------------------
# Exact reductions over dyadic rationals. value = num * 2^scale with num
# an arbitrary-precision integer — the software stand-in for the quire.
# ----------------------------------------------------------------------


def _exact_to_float(num, scale):
    """Correctly-rounded (RNE) f64 of num * 2^scale."""
    if num == 0:
        return 0.0
    if scale >= 0:
        return float(num << scale)
    return float(Fraction(num, 1 << -scale))


def exact_dot(a, b):
    """sum(a[i]*b[i]) accumulated exactly, one RNE rounding to f64 —
    mirrors quire_dot readout (to_decoded().to_f64())."""
    num, scale = 0, 0
    for x, y in zip(a, b):
        if x == 0.0 or y == 0.0:
            continue
        px, qx = x.as_integer_ratio()
        py, qy = y.as_integer_ratio()
        p = px * py
        s = -((qx * qy).bit_length() - 1)  # q's are powers of two
        if s < scale:
            num <<= scale - s
            scale = s
        num += p << (s - scale)
    return _exact_to_float(num, scale)


def exact_norm(v):
    """sqrt of the exact self-dot — the tier-independent residual metric."""
    return math.sqrt(exact_dot(v, v))


# ----------------------------------------------------------------------
# Dense 8-accumulator fast dot (rust/src/vector/kernels.rs::dot) and the
# chunk-aware sparse row kernel (rust/src/vector/sparse.rs) that must
# match it bitwise on densified matrices.
# ----------------------------------------------------------------------


def dense_dot_fast(ops, a, b):
    n = len(a)
    chunks = n - n % 8
    acc = [0.0] * 8
    i = 0
    while i < chunks:
        for lane in range(8):
            acc[lane] = ops.add(acc[lane], ops.mul(a[i + lane], b[i + lane]))
        i += 8
    s = ops.add(
        ops.add(ops.add(acc[0], acc[4]), ops.add(acc[1], acc[5])),
        ops.add(ops.add(acc[2], acc[6]), ops.add(acc[3], acc[7])),
    )
    while i < n:
        s = ops.add(s, ops.mul(a[i], b[i]))
        i += 1
    return s


def sparse_row_dot_fast(ops, idx, vals, x, chunks):
    """Chunk-aware CSR row kernel: stored entry at column c lands in
    accumulator c & 7 while c < chunks, then the serial tail — the same
    per-accumulator addition order and combine tree as the dense kernel,
    so skipping the (bitwise-inert) zero products changes nothing."""
    acc = [0.0] * 8
    k = 0
    while k < len(idx) and idx[k] < chunks:
        c = idx[k]
        acc[c & 7] = ops.add(acc[c & 7], ops.mul(vals[k], x[c]))
        k += 1
    s = ops.add(
        ops.add(ops.add(acc[0], acc[4]), ops.add(acc[1], acc[5])),
        ops.add(ops.add(acc[2], acc[6]), ops.add(acc[3], acc[7])),
    )
    while k < len(idx):
        s = ops.add(s, ops.mul(vals[k], x[idx[k]]))
        k += 1
    return s


# ----------------------------------------------------------------------
# SplitMix64 — bitwise mirror of rust/src/testutil/mod.rs::Rng.
# ----------------------------------------------------------------------

_M64 = (1 << 64) - 1


class Rng:
    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & _M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return z ^ (z >> 31)

    def below(self, n):
        return self.next_u64() % n

    def f64(self):
        return (self.next_u64() >> 11) / (1 << 53)


# ----------------------------------------------------------------------
# Operators — bitwise mirrors of rust/src/solver/ operators. A matrix is
# rows = [[(col, val), ...] ascending col, ...] (the CSR contract).
# ----------------------------------------------------------------------


def poisson2d(g):
    """5-point 2D Poisson stencil on a g x g grid (Dirichlet), n = g^2.
    All values are small integers: exactly representable in every tier."""
    n = g * g
    rows = []
    for i in range(g):
        for j in range(g):
            k = i * g + j
            row = []
            if i > 0:
                row.append((k - g, -1.0))
            if j > 0:
                row.append((k - 1, -1.0))
            row.append((k, 4.0))
            if j < g - 1:
                row.append((k + 1, -1.0))
            if i < g - 1:
                row.append((k + g, -1.0))
            rows.append(row)
    return rows


def rand_dd(n, offdiag, scale_pow, seed):
    """Random symmetric diagonally-dominant SPD operator with power-of-2
    row/column scaling (exact in binary FP): A'_ij = s_i * s_j * A_ij,
    s_i = 2^e_i, e_i uniform in [-scale_pow, scale_pow]. The unscaled A
    has unit diagonal dominance margin; the scaling skews the diagonal
    over ~2^(2*scale_pow), which plain CG pays for and Jacobi removes."""
    rng = Rng(seed)
    offd = {}
    for i in range(n):
        for _ in range(offdiag):
            j = rng.below(n)
            if j == i:
                continue
            key = (min(i, j), max(i, j))
            if key not in offd:
                offd[key] = (rng.f64() - 0.5) * 2.0
    exps = [int(rng.below(2 * scale_pow + 1)) - scale_pow for _ in range(n)]
    rows = [[] for _ in range(n)]
    for (i, j), v in offd.items():
        rows[i].append((j, v))
        rows[j].append((i, v))
    for r in rows:
        r.sort()
    for i in range(n):
        diag = 1.0
        for _, v in rows[i]:
            diag += abs(v)
        rows[i].append((i, diag))
        rows[i].sort()
    scaled = []
    for i in range(n):
        si = math.ldexp(1.0, exps[i])
        scaled.append([(j, v * si * math.ldexp(1.0, exps[j])) for j, v in rows[i]])
    return scaled


def densify(rows, cols):
    out = []
    for row in rows:
        dense = [0.0] * cols
        for c, v in row:
            dense[c] = v
        out.append(dense)
    return out


# ----------------------------------------------------------------------
# Tiered CG — mirror of rust/src/solver/mod.rs::cg. Reductions are fast
# (the 8-acc kernel) or quire-exact; scalars always travel as f64 and are
# rounded to the tier width before vector updates; the residual trajectory
# is the exact norm in every tier.
# ----------------------------------------------------------------------


def spmv_fast(ops, rows, chunks, x):
    return [sparse_row_dot_fast(ops, [c for c, _ in r], [v for _, v in r], x, chunks) for r in rows]


def spmv_quire(ops, rows, x):
    return [ops.rnd(exact_dot([v for _, v in r], [x[c] for c, _ in r])) for r in rows]


def cg(rows, b, ops, quire, tol, max_iters, jacobi):
    n = len(b)
    chunks = n - n % 8
    inv_diag = None
    if jacobi:
        diag = {r: dict(rows[r])[r] for r in range(n)}
        inv_diag = [ops.rnd(1.0 / diag[r]) for r in range(n)]
    x = [0.0] * n
    r = [ops.rnd(v) for v in b]

    def apply_m(vec):
        if inv_diag is None:
            return list(vec)
        return [ops.mul(vec[i], inv_diag[i]) for i in range(n)]

    def dot_t(u, v):
        if quire:
            return exact_dot(u, v)
        return dense_dot_fast(ops, u, v)

    def spmv_t(vec):
        if quire:
            return spmv_quire(ops, rows, vec)
        return spmv_fast(ops, rows, chunks, vec)

    z = apply_m(r)
    p = list(z)
    rz = dot_t(r, z)
    norm_b = exact_norm(b)
    threshold = tol * norm_b
    residuals = []
    converged = False
    breakdown = False
    k = 0
    while True:
        res = exact_norm(r)
        residuals.append(res)
        if res <= threshold:
            converged = True
            break
        if k == max_iters:
            break
        ap = spmv_t(p)
        pap = dot_t(p, ap)
        if not pap > 0.0 or not math.isfinite(pap):
            breakdown = True
            break
        alpha = rz / pap
        alpha_e = ops.rnd(alpha)
        for i in range(n):
            x[i] = ops.add(x[i], ops.mul(alpha_e, p[i]))
        for i in range(n):
            r[i] = ops.sub(r[i], ops.mul(alpha_e, ap[i]))
        z = apply_m(r)
        rz_new = dot_t(r, z)
        beta = rz_new / rz
        beta_e = ops.rnd(beta)
        for i in range(n):
            p[i] = ops.add(z[i], ops.mul(beta_e, p[i]))
        rz = rz_new
        k += 1
    return {
        "iterations": k,
        "converged": converged,
        "breakdown": breakdown,
        "residuals": residuals,
        "x": x,
    }


# ----------------------------------------------------------------------
# Tests (pure asserts; pytest is only the runner).
# ----------------------------------------------------------------------


def _random_sparse_case(rng, rows_n, cols_n, fill_pm0):
    """Dense matrix with structural zeros (and, when fill_pm0, stored -0.0
    entries) plus a mixed-sign x vector."""
    dense = [[0.0] * cols_n for _ in range(rows_n)]
    sparse = []
    for r in range(rows_n):
        row = []
        for c in range(cols_n):
            roll = rng.below(4)
            if roll == 0:
                continue
            if fill_pm0 and roll == 1:
                v = -0.0
            else:
                v = (rng.f64() - 0.5) * math.ldexp(1.0, int(rng.below(13)) - 6)
            dense[r][c] = v
            row.append((c, v))
        sparse.append(row)
    x = [(rng.f64() - 0.5) * 4.0 for _ in range(cols_n)]
    return dense, sparse, x


def test_sparse_fast_matches_dense_bitwise():
    for ops, bits in ((F64Ops, f64_bits), (F32Ops, f32_bits)):
        rng = Rng(0xC5A_0001)
        for case in range(40):
            rows_n = 1 + int(rng.below(6))
            cols_n = 1 + int(rng.below(37))
            dense, sparse, x = _random_sparse_case(rng, rows_n, cols_n, case % 2 == 0)
            if ops is F32Ops:
                dense = [[f32r(v) for v in row] for row in dense]
                sparse = [[(c, f32r(v)) for c, v in row] for row in sparse]
                x = [f32r(v) for v in x]
            chunks = cols_n - cols_n % 8
            for r in range(rows_n):
                want = dense_dot_fast(ops, dense[r], x)
                got = sparse_row_dot_fast(
                    ops, [c for c, _ in sparse[r]], [v for _, v in sparse[r]], x, chunks
                )
                assert bits(got) == bits(want), (ops.name, case, r, got, want)


def test_sparse_quire_matches_dense_exact():
    rng = Rng(0xC5A_0002)
    for case in range(20):
        rows_n = 1 + int(rng.below(5))
        cols_n = 1 + int(rng.below(29))
        dense, sparse, x = _random_sparse_case(rng, rows_n, cols_n, case % 2 == 0)
        for r in range(rows_n):
            want = exact_dot(dense[r], x)
            got = exact_dot([v for _, v in sparse[r]], [x[c] for c, _ in sparse[r]])
            assert f64_bits(want) == f64_bits(got), (case, r)


def test_poisson_is_symmetric_and_dd():
    rows = poisson2d(5)
    dense = densify(rows, 25)
    for i in range(25):
        assert dense[i][i] == 4.0
        for j in range(25):
            assert dense[i][j] == dense[j][i]
        assert sum(abs(dense[i][j]) for j in range(25) if j != i) <= 4.0


def test_rand_dd_is_symmetric_spd_shaped():
    # Unscaled: strictly diagonally dominant (Gershgorin SPD). Scaled:
    # A' = D A D with D a positive power-of-2 diagonal — a congruence, so
    # still SPD (and still exactly symmetric: *2^k is exact), though no
    # longer diagonally dominant. That skew is the point: it is what the
    # Jacobi variant removes.
    unscaled = rand_dd(48, 3, 0, 7)
    dense = densify(unscaled, 48)
    for i in range(48):
        offsum = sum(abs(dense[i][j]) for j in range(48) if j != i)
        # 0.5 margin: the constructor folds the +1.0 in first, so the two
        # summation orders can differ by an ulp.
        assert dense[i][i] >= offsum + 0.5
    scaled = densify(rand_dd(48, 3, 6, 7), 48)
    for i in range(48):
        assert scaled[i][i] > 0.0
        for j in range(48):
            assert f64_bits(scaled[i][j]) == f64_bits(scaled[j][i])


def test_quire_tier_beats_or_ties_f32_on_small_poisson_set():
    # The CI bench gate's ordering claim, on the --small operator set.
    for g in (8, 16):
        rows = poisson2d(g)
        b = [1.0] * (g * g)
        fast = cg(rows, b, F32Ops, quire=False, tol=1e-6, max_iters=400, jacobi=False)
        exact = cg(rows, b, F32Ops, quire=True, tol=1e-6, max_iters=400, jacobi=False)
        assert exact["converged"]
        assert exact["iterations"] <= fast["iterations"], (g, exact, fast)


def test_jacobi_strictly_wins_on_scaled_dd():
    rows = rand_dd(96, 3, 8, 11)
    b = [1.0] * 96
    plain = cg(rows, b, F64Ops, quire=False, tol=1e-6, max_iters=200, jacobi=False)
    pre = cg(rows, b, F64Ops, quire=False, tol=1e-6, max_iters=200, jacobi=True)
    assert pre["converged"]
    assert pre["iterations"] < plain["iterations"], (pre["iterations"], plain["iterations"])


def test_jacobi_is_exact_rescale_on_poisson():
    # Constant diagonal 4 = 2^2: Jacobi is an exact power-of-two rescale,
    # so the trajectory is bitwise unchanged (the Rust test asserts <=).
    rows = poisson2d(8)
    b = [1.0] * 64
    plain = cg(rows, b, F64Ops, quire=False, tol=1e-6, max_iters=400, jacobi=False)
    pre = cg(rows, b, F64Ops, quire=False, tol=1e-6, max_iters=400, jacobi=True)
    assert pre["iterations"] == plain["iterations"]
    assert [f64_bits(v) for v in pre["residuals"]] == [f64_bits(v) for v in plain["residuals"]]


# Golden trajectories for rust/tests/solver.rs (generated by
# `--emit-goldens` below; regenerate if the CG recurrence ever changes).
GOLDEN_SPEC = dict(grid=8, tol=1e-6, max_iters=400)


def golden_runs():
    rows = poisson2d(GOLDEN_SPEC["grid"])
    b = [1.0] * (GOLDEN_SPEC["grid"] ** 2)
    qk = dict(tol=GOLDEN_SPEC["tol"], max_iters=GOLDEN_SPEC["max_iters"], jacobi=False)
    return {
        "quire64": cg(rows, b, F64Ops, quire=True, **qk),
        "f32": cg(rows, b, F32Ops, quire=False, **qk),
    }


def test_golden_trajectories_are_stable():
    runs = golden_runs()
    assert [f64_bits(v) for v in runs["quire64"]["residuals"][:3]] == [
        0x4020000000000000,
        0x4023988E1409212E,
        0x401BD3E5C6F0E027,
    ]
    assert runs["quire64"]["converged"] and runs["f32"]["converged"]


def emit_goldens():
    runs = golden_runs()
    for name, run in runs.items():
        print(f"// tier {name}: iterations={run['iterations']} converged={run['converged']}")
        print(f"const GOLDEN_{name.upper()}_RESIDUALS: &[u64] = &[")
        for v in run["residuals"]:
            print(f"    0x{f64_bits(v):016x},")
        print("];")
    xq = runs["quire64"]["x"]
    print("const GOLDEN_QUIRE64_X: &[u64] = &[")
    for v in xq:
        print(f"    0x{f64_bits(v):016x},")
    print("];")


if __name__ == "__main__":
    import sys

    if "--emit-goldens" in sys.argv:
        emit_goldens()
    else:
        fails = 0
        for name, fn in sorted(globals().items()):
            if name.startswith("test_") and callable(fn):
                try:
                    fn()
                    print(f"PASS {name}")
                except AssertionError as e:
                    fails += 1
                    print(f"FAIL {name}: {e}")
        sys.exit(1 if fails else 0)
