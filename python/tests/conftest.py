"""Test scaffolding: make `compile.*` importable from any invocation
directory, and skip collection of suites whose heavyweight deps (jax,
hypothesis, numpy) are absent — the pure-stdlib oracle tests in
test_scalar_oracle.py always run, so `python -m pytest python/tests -q`
passes on a bare interpreter."""

import importlib.util
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

collect_ignore = []
if any(importlib.util.find_spec(m) is None for m in ("jax", "numpy", "hypothesis")):
    collect_ignore = ["test_codec.py", "test_kernel.py", "test_model.py"]
